//! **`BatchOp`** — a batch axis for the operator algebra: a stack of
//! same-shape [`LinearOp`]s treated as one block-diagonal system, so b
//! independent solves run through **one** iteration loop (`mbcg_batch`)
//! and, in serving, one dispatcher call per tick answers every tenant.
//!
//! The structure-aware fast path is the paper's batching argument applied
//! across *operators* instead of right-hand sides: when every element is
//! `K + σᵢ²I` over a **shared** covariance `K` (hyperparameter sweeps, a
//! fleet of per-tenant noise levels over one dataset), the per-iteration
//! work for the whole batch is a single `K·[D₁ … D_b]` product — the
//! expensive kernel-row generation is paid once, not b times — plus one
//! cheap per-element `σᵢ²·Dᵢ` axpy. General batches (different operators
//! per element, as in multi-tenant serving) apply elementwise.
//!
//! Composition lifts ([`lift_sum`], [`lift_scaled`], [`lift_low_rank`],
//! [`lift_added_diag`]) build element vectors from the existing algebra so
//! a batch of composed models is written the same way a single one is.

use super::{AddedDiagOp, LinearOp, LowRankOp, ScaledOp, SumOp};
use crate::tensor::Mat;

/// Thin-pointer identity of a trait object (ignores the vtable, which can
/// legitimately differ across codegen units for the same value).
fn data_ptr(op: &dyn LinearOp) -> *const () {
    op as *const dyn LinearOp as *const ()
}

enum Repr<'a> {
    /// arbitrary same-shape operators, applied elementwise
    General(Vec<&'a dyn LinearOp>),
    /// every element is `cov + σᵢ²I` over one shared covariance
    Shared {
        cov: &'a dyn LinearOp,
        sigma2s: Vec<f64>,
    },
}

/// A stack of `b` same-shape [`LinearOp`]s with batched products — see the
/// module docs for the shared-covariance fast path.
pub struct BatchOp<'a> {
    repr: Repr<'a>,
}

impl<'a> BatchOp<'a> {
    /// Stack same-shape operators. If every element exposes a
    /// [`LinearOp::noise_split`] over the **same** inner operator (pointer
    /// identity), the shared fast path is engaged automatically; callers
    /// that construct per-batch `AddedDiagOp` wrappers around one
    /// covariance should use [`BatchOp::shared`] directly, since each
    /// wrapper borrows the covariance through its own field and pointer
    /// detection cannot see through that.
    pub fn new(elements: Vec<&'a dyn LinearOp>) -> Self {
        assert!(!elements.is_empty(), "BatchOp: empty batch");
        let shape = elements[0].shape();
        for &e in &elements {
            assert_eq!(e.shape(), shape, "BatchOp: shape mismatch");
        }
        // opportunistic shared-covariance detection
        let mut sigma2s = Vec::with_capacity(elements.len());
        let mut cov: Option<&'a dyn LinearOp> = None;
        let mut shared = true;
        for &e in &elements {
            match e.noise_split() {
                Some((inner, s2)) if s2 > 0.0 => {
                    match cov {
                        None => cov = Some(inner),
                        Some(c) if data_ptr(c) == data_ptr(inner) => {}
                        Some(_) => {
                            shared = false;
                            break;
                        }
                    }
                    sigma2s.push(s2);
                }
                _ => {
                    shared = false;
                    break;
                }
            }
        }
        match (shared, cov) {
            (true, Some(cov)) => BatchOp {
                repr: Repr::Shared { cov, sigma2s },
            },
            _ => BatchOp {
                repr: Repr::General(elements),
            },
        }
    }

    /// Stack operators of **different** shapes (heterogeneous serving:
    /// tenants of different n, different model families, one batch).
    /// Always the elementwise representation — there is no shared
    /// covariance across sizes — so every batched product dispatches each
    /// element's own structured `matmul_into`. Consumers that size
    /// per-element buffers must use [`BatchOp::element_n`], not
    /// [`BatchOp::n`].
    pub fn hetero(elements: Vec<&'a dyn LinearOp>) -> Self {
        assert!(!elements.is_empty(), "BatchOp: empty batch");
        for &e in &elements {
            let (r, c) = e.shape();
            assert_eq!(r, c, "BatchOp: hetero elements must be square");
        }
        BatchOp {
            repr: Repr::General(elements),
        }
    }

    /// Dimension of element `i` (elements of a [`BatchOp::hetero`] batch
    /// differ; for uniform batches this equals [`BatchOp::n`]).
    pub fn element_n(&self, i: usize) -> usize {
        match &self.repr {
            Repr::General(els) => els[i].n(),
            Repr::Shared { cov, .. } => cov.n(),
        }
    }

    /// The explicit shared fast path: element `i` is `cov + sigma2s[i]·I`.
    pub fn shared(cov: &'a dyn LinearOp, sigma2s: Vec<f64>) -> Self {
        assert!(!sigma2s.is_empty(), "BatchOp: empty batch");
        assert!(
            sigma2s.iter().all(|&s| s > 0.0),
            "BatchOp: added diagonals must be positive"
        );
        BatchOp {
            repr: Repr::Shared { cov, sigma2s },
        }
    }

    /// Number of stacked operators `b`.
    pub fn len(&self) -> usize {
        match &self.repr {
            Repr::General(els) => els.len(),
            Repr::Shared { sigma2s, .. } => sigma2s.len(),
        }
    }

    /// True when the batch is empty (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Dimension `n` shared by every element.
    pub fn n(&self) -> usize {
        match &self.repr {
            Repr::General(els) => els[0].n(),
            Repr::Shared { cov, .. } => cov.n(),
        }
    }

    /// True when the shared-covariance fast path is engaged.
    pub fn is_shared(&self) -> bool {
        matches!(self.repr, Repr::Shared { .. })
    }

    /// Build every element's plan-dependent materialisations now (the
    /// shared fast path prepares its one covariance once) — called by
    /// [`crate::linalg::mbcg::mbcg_batch`] before the iteration loop so
    /// the loop itself starts warm.
    pub fn prepare(&self) {
        match &self.repr {
            Repr::General(els) => {
                for e in els {
                    e.prepare();
                }
            }
            Repr::Shared { cov, .. } => cov.prepare(),
        }
    }

    /// The shared covariance and per-element σ² when the fast path is
    /// engaged (the batched preconditioner builder pivots on this).
    pub fn shared_parts(&self) -> Option<(&dyn LinearOp, &[f64])> {
        match &self.repr {
            Repr::General(_) => None,
            Repr::Shared { cov, sigma2s } => Some((*cov, sigma2s)),
        }
    }

    /// Run `f` against element `i` as a full [`LinearOp`] (for the shared
    /// representation the `AddedDiagOp` view is materialised on the fly —
    /// a zero-copy wrapper, not a matrix).
    pub fn with_element<R>(&self, i: usize, f: impl FnOnce(&dyn LinearOp) -> R) -> R {
        match &self.repr {
            Repr::General(els) => f(els[i]),
            Repr::Shared { cov, sigma2s } => {
                let view = AddedDiagOp::new(*cov, sigma2s[i]);
                f(&view)
            }
        }
    }

    /// The sub-batch of elements `idx` (preserving representation).
    pub fn subset(&self, idx: &[usize]) -> BatchOp<'a> {
        match &self.repr {
            Repr::General(els) => BatchOp {
                repr: Repr::General(idx.iter().map(|&i| els[i]).collect()),
            },
            Repr::Shared { cov, sigma2s } => BatchOp {
                repr: Repr::Shared {
                    cov: *cov,
                    sigma2s: idx.iter().map(|&i| sigma2s[i]).collect(),
                },
            },
        }
    }

    /// Batched product: `out[k] = A_{idx[k]} · ms[k]` (`idx` must be
    /// distinct — each index addresses its own output). A thin allocating
    /// wrapper over [`BatchOp::matmul_subset_into`], the single
    /// implementation of the shared-path pack/multiply/unpack.
    pub fn matmul_subset(&self, idx: &[usize], ms: &[&Mat]) -> Vec<Mat> {
        assert_eq!(idx.len(), ms.len());
        let slots = idx.iter().map(|&i| i + 1).max().unwrap_or(0);
        let mut pos = vec![usize::MAX; slots];
        for (k, &i) in idx.iter().enumerate() {
            assert!(pos[i] == usize::MAX, "BatchOp: duplicate subset index {i}");
            pos[i] = k;
        }
        let mut outs: Vec<Mat> = (0..slots)
            .map(|i| {
                if pos[i] == usize::MAX {
                    Mat::zeros(0, 0)
                } else {
                    Mat::zeros(self.element_n(i), ms[pos[i]].cols())
                }
            })
            .collect();
        let (mut block, mut kv) = (Vec::new(), Vec::new());
        self.matmul_subset_into(idx, |i| ms[pos[i]], &mut outs, &mut block, &mut kv);
        idx.iter()
            .map(|&i| std::mem::replace(&mut outs[i], Mat::zeros(0, 0)))
            .collect()
    }

    /// The allocation-free core of [`BatchOp::matmul_subset`], shaped for
    /// iteration loops: write `outs[i] = A_i · get_m(i)` for each distinct
    /// `i` in `idx` (outputs are indexed by batch element, so `outs` spans
    /// the whole batch and untouched slots may be empty placeholders). The
    /// shared path concatenates the right-hand blocks through the caller's
    /// `block` scratch, pays **one** covariance product into `kv`, and adds
    /// the per-element σ²·M axpy while splitting the result back —
    /// column-for-column identical to the elementwise products (each
    /// column's accumulation order is unchanged). Scratch buffers only grow
    /// on demand, so callers that pre-size them (the mBCG workspace) see a
    /// heap-free call. Returns the number of operator products performed
    /// (1 on the shared path, `idx.len()` elementwise).
    pub fn matmul_subset_into<'m>(
        &self,
        idx: &[usize],
        get_m: impl Fn(usize) -> &'m Mat,
        outs: &mut [Mat],
        block: &mut Vec<f64>,
        kv: &mut Vec<f64>,
    ) -> usize {
        match &self.repr {
            Repr::General(els) => {
                for &i in idx {
                    els[i].matmul_into(get_m(i), &mut outs[i]);
                }
                idx.len()
            }
            Repr::Shared { cov, sigma2s } => {
                let n = cov.n();
                let total: usize = idx.iter().map(|&i| get_m(i).cols()).sum();
                let mut block_data = std::mem::take(block);
                if block_data.len() < n * total {
                    block_data.resize(n * total, 0.0);
                }
                block_data.truncate(n * total);
                for r in 0..n {
                    let mut c0 = r * total;
                    for &i in idx {
                        let m = get_m(i);
                        assert_eq!(m.rows(), n, "BatchOp: RHS row mismatch");
                        let mrow = m.row(r);
                        block_data[c0..c0 + mrow.len()].copy_from_slice(mrow);
                        c0 += mrow.len();
                    }
                }
                let packed = Mat::from_vec(n, total, block_data);
                let mut kv_data = std::mem::take(kv);
                if kv_data.len() < n * total {
                    kv_data.resize(n * total, 0.0);
                }
                kv_data.truncate(n * total);
                let mut prod = Mat::from_vec(n, total, kv_data);
                cov.matmul_into(&packed, &mut prod);
                for r in 0..n {
                    let kvrow = prod.row(r);
                    let mut c0 = 0;
                    for &i in idx {
                        let s2 = sigma2s[i];
                        let m = get_m(i);
                        let t = m.cols();
                        let mrow = m.row(r);
                        let orow = &mut outs[i].row_mut(r)[..t];
                        for c in 0..t {
                            orow[c] = kvrow[c0 + c] + s2 * mrow[c];
                        }
                        c0 += t;
                    }
                }
                *block = packed.into_vec();
                *kv = prod.into_vec();
                1
            }
        }
    }

    /// Batched product over the full batch: `out[i] = A_i · ms[i]`.
    pub fn matmul_multi(&self, ms: &[&Mat]) -> Vec<Mat> {
        assert_eq!(ms.len(), self.len());
        let idx: Vec<usize> = (0..self.len()).collect();
        self.matmul_subset(&idx, ms)
    }
}

/// Lift [`SumOp`] elementwise: `out[i] = a[i] + b[i]`.
pub fn lift_sum<A: LinearOp, B: LinearOp>(a: Vec<A>, b: Vec<B>) -> Vec<SumOp<A, B>> {
    assert_eq!(a.len(), b.len(), "lift_sum: batch size mismatch");
    a.into_iter().zip(b).map(|(x, y)| SumOp::new(x, y)).collect()
}

/// Lift [`ScaledOp`] elementwise: `out[i] = cs[i] · a[i]`.
pub fn lift_scaled<A: LinearOp>(a: Vec<A>, cs: &[f64]) -> Vec<ScaledOp<A>> {
    assert_eq!(a.len(), cs.len(), "lift_scaled: batch size mismatch");
    a.into_iter()
        .zip(cs)
        .map(|(x, &c)| ScaledOp::new(x, c))
        .collect()
}

/// Lift [`LowRankOp`] elementwise: `out[i] = Lᵢ·Lᵢᵀ`.
pub fn lift_low_rank(factors: Vec<Mat>) -> Vec<LowRankOp> {
    factors.into_iter().map(LowRankOp::new).collect()
}

/// Lift [`AddedDiagOp`] elementwise: `out[i] = inner[i] + sigma2s[i]·I`.
pub fn lift_added_diag<A: LinearOp>(inners: Vec<A>, sigma2s: &[f64]) -> Vec<AddedDiagOp<A>> {
    assert_eq!(inners.len(), sigma2s.len(), "lift_added_diag: batch size mismatch");
    inners
        .into_iter()
        .zip(sigma2s)
        .map(|(x, &s)| AddedDiagOp::new(x, s))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::op::DenseOp;
    use crate::util::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = g.t_matmul(&g);
        a.add_diag(0.5);
        a.symmetrize();
        a
    }

    #[test]
    fn shared_batch_matmul_matches_elementwise_exactly() {
        let n = 30;
        let cov = DenseOp::new(spd(n, 1));
        let sigma2s = vec![0.1, 0.5, 1.3, 0.02];
        let batch = BatchOp::shared(&cov, sigma2s.clone());
        assert!(batch.is_shared());
        assert_eq!(batch.len(), 4);
        assert_eq!(batch.n(), n);
        let mut rng = Rng::new(2);
        let ms: Vec<Mat> = (0..4)
            .map(|k| Mat::from_fn(n, 1 + k % 3, |_, _| rng.normal()))
            .collect();
        let mrefs: Vec<&Mat> = ms.iter().collect();
        let got = batch.matmul_multi(&mrefs);
        for (k, m) in ms.iter().enumerate() {
            let element = AddedDiagOp::new(&cov, sigma2s[k]);
            let want = element.matmul(m);
            assert!(
                got[k].max_abs_diff(&want) == 0.0,
                "element {k}: {}",
                got[k].max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn general_batch_applies_elementwise() {
        let n = 20;
        let a = DenseOp::new(spd(n, 3));
        let b = DenseOp::new(spd(n, 4));
        let batch = BatchOp::new(vec![&a as &dyn LinearOp, &b as &dyn LinearOp]);
        assert!(!batch.is_shared());
        let mut rng = Rng::new(5);
        let m1 = Mat::from_fn(n, 2, |_, _| rng.normal());
        let m2 = Mat::from_fn(n, 3, |_, _| rng.normal());
        let got = batch.matmul_multi(&[&m1, &m2]);
        assert!(got[0].max_abs_diff(&a.matmul(&m1)) == 0.0);
        assert!(got[1].max_abs_diff(&b.matmul(&m2)) == 0.0);
    }

    #[test]
    fn subset_preserves_elements_and_sigmas() {
        let n = 12;
        let cov = DenseOp::new(spd(n, 6));
        let batch = BatchOp::shared(&cov, vec![0.1, 0.2, 0.3]);
        let sub = batch.subset(&[2, 0]);
        assert_eq!(sub.len(), 2);
        let (_, sigmas) = sub.shared_parts().unwrap();
        assert_eq!(sigmas, &[0.3, 0.1]);
        let mut rng = Rng::new(7);
        let m = Mat::from_fn(n, 2, |_, _| rng.normal());
        let got = sub.matmul_subset(&[0, 1], &[&m, &m]);
        let want0 = AddedDiagOp::new(&cov, 0.3).matmul(&m);
        let want1 = AddedDiagOp::new(&cov, 0.1).matmul(&m);
        assert!(got[0].max_abs_diff(&want0) == 0.0);
        assert!(got[1].max_abs_diff(&want1) == 0.0);
    }

    #[test]
    fn with_element_materialises_the_added_diag_view() {
        let n = 10;
        let cov = DenseOp::new(spd(n, 8));
        let batch = BatchOp::shared(&cov, vec![0.4, 0.9]);
        let d1 = batch.with_element(1, |op| op.diag());
        for (i, v) in d1.iter().enumerate() {
            assert!((v - (cov.entry(i, i) + 0.9)).abs() < 1e-15);
        }
        let s2 = batch.with_element(0, |op| op.noise());
        assert!((s2 - 0.4).abs() < 1e-15);
    }

    #[test]
    fn detection_engages_on_pointer_shared_noise_split() {
        // one AddedDiagOp referenced twice: both elements split to the
        // same inner pointer, so the batch collapses to the shared path
        let n = 8;
        let cov = DenseOp::new(spd(n, 9));
        let op = AddedDiagOp::new(cov, 0.25);
        let batch = BatchOp::new(vec![&op as &dyn LinearOp, &op as &dyn LinearOp]);
        assert!(batch.is_shared());
        let (_, sigmas) = batch.shared_parts().unwrap();
        assert_eq!(sigmas, &[0.25, 0.25]);
    }

    #[test]
    fn lifts_compose_elementwise() {
        let n = 15;
        let mut rng = Rng::new(10);
        let factors: Vec<Mat> = (0..3)
            .map(|_| Mat::from_fn(n, 4, |_, _| rng.normal()))
            .collect();
        let want_dense: Vec<Mat> = factors
            .iter()
            .map(|l| {
                let mut k = l.matmul_t(l);
                k.scale_assign(2.0);
                k.add_diag(0.1);
                k
            })
            .collect();
        let lifted = lift_added_diag(
            lift_scaled(lift_low_rank(factors), &[2.0, 2.0, 2.0]),
            &[0.1, 0.1, 0.1],
        );
        let els: Vec<&dyn LinearOp> = lifted.iter().map(|o| o as &dyn LinearOp).collect();
        let batch = BatchOp::new(els);
        assert_eq!(batch.len(), 3);
        let m = Mat::from_fn(n, 2, |_, _| rng.normal());
        let got = batch.matmul_multi(&[&m, &m, &m]);
        for k in 0..3 {
            assert!(got[k].max_abs_diff(&want_dense[k].matmul(&m)) < 1e-10, "element {k}");
        }
    }

    #[test]
    fn hetero_batch_applies_per_element_shapes() {
        let a = DenseOp::new(spd(9, 21));
        let b = DenseOp::new(spd(14, 22));
        let batch = BatchOp::hetero(vec![&a as &dyn LinearOp, &b as &dyn LinearOp]);
        assert!(!batch.is_shared());
        assert_eq!(batch.len(), 2);
        assert_eq!(batch.element_n(0), 9);
        assert_eq!(batch.element_n(1), 14);
        let mut rng = Rng::new(23);
        let m1 = Mat::from_fn(9, 2, |_, _| rng.normal());
        let m2 = Mat::from_fn(14, 3, |_, _| rng.normal());
        let got = batch.matmul_multi(&[&m1, &m2]);
        assert!(got[0].max_abs_diff(&a.matmul(&m1)) == 0.0);
        assert!(got[1].max_abs_diff(&b.matmul(&m2)) == 0.0);
        // subsets preserve per-element shapes
        let sub = batch.subset(&[1]);
        assert_eq!(sub.element_n(0), 14);
        let got = sub.matmul_subset(&[0], &[&m2]);
        assert!(got[0].max_abs_diff(&b.matmul(&m2)) == 0.0);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn mismatched_shapes_rejected() {
        let a = DenseOp::new(spd(5, 11));
        let b = DenseOp::new(spd(6, 12));
        let _ = BatchOp::new(vec![&a as &dyn LinearOp, &b as &dyn LinearOp]);
    }
}
