//! Structure wrappers: [`KroneckerOp`] (`A ⊗ B`, multi-task GPs) and
//! [`ToeplitzLinOp`] (symmetric Toeplitz via FFT, KISS-GP's grid kernel) —
//! the existing routines in [`crate::linalg::kronecker`] and
//! [`crate::linalg::toeplitz`] lifted into the operator algebra so they
//! compose with everything else.

use super::{LinearOp, SolveHint};
use crate::linalg::kronecker::{kron_dense, kron_matmul};
use crate::linalg::toeplitz::ToeplitzOp;
use crate::tensor::Mat;

/// `A ⊗ B` for dense square factors. Vector layout pairs A-index `i` with
/// B-index `j` at position `i·qb + j` (see [`crate::linalg::kronecker`]);
/// a matmul costs two small GEMMs per column instead of one (qa·qb)² one.
pub struct KroneckerOp {
    a: Mat,
    b: Mat,
}

impl KroneckerOp {
    /// Compose `a ⊗ b` (both square).
    pub fn new(a: Mat, b: Mat) -> Self {
        assert_eq!(a.rows(), a.cols(), "A must be square");
        assert_eq!(b.rows(), b.cols(), "B must be square");
        KroneckerOp { a, b }
    }

    /// Left factor `A`.
    pub fn a(&self) -> &Mat {
        &self.a
    }

    /// Right factor `B`.
    pub fn b(&self) -> &Mat {
        &self.b
    }
}

impl LinearOp for KroneckerOp {
    fn shape(&self) -> (usize, usize) {
        let n = self.a.rows() * self.b.rows();
        (n, n)
    }

    fn matmul(&self, m: &Mat) -> Mat {
        kron_matmul(&self.a, &self.b, m)
    }

    fn diag(&self) -> Vec<f64> {
        let (qa, qb) = (self.a.rows(), self.b.rows());
        let mut d = Vec::with_capacity(qa * qb);
        for i in 0..qa {
            let ai = self.a.get(i, i);
            for j in 0..qb {
                d.push(ai * self.b.get(j, j));
            }
        }
        d
    }

    fn row(&self, idx: usize) -> Vec<f64> {
        let qb = self.b.rows();
        let (i, s) = (idx / qb, idx % qb);
        let arow = self.a.row(i);
        let brow = self.b.row(s);
        let mut r = Vec::with_capacity(self.a.rows() * qb);
        for &av in arow {
            for &bv in brow {
                r.push(av * bv);
            }
        }
        r
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        let qb = self.b.rows();
        self.a.get(i / qb, j / qb) * self.b.get(i % qb, j % qb)
    }

    fn dense(&self) -> Mat {
        kron_dense(&self.a, &self.b)
    }
}

/// Symmetric Toeplitz matrix `T[i,j] = c[|i−j|]` with O(m log m) matmuls
/// via the circulant-embedding FFT in [`crate::linalg::toeplitz`].
pub struct ToeplitzLinOp {
    t: ToeplitzOp,
}

impl ToeplitzLinOp {
    /// Build from the first column of the Toeplitz matrix.
    pub fn new(first_column: Vec<f64>) -> Self {
        ToeplitzLinOp {
            t: ToeplitzOp::new(first_column),
        }
    }

    /// Wrap an existing FFT-ready Toeplitz operator.
    pub fn from_op(t: ToeplitzOp) -> Self {
        ToeplitzLinOp { t }
    }

    /// The underlying FFT operator.
    pub fn toeplitz(&self) -> &ToeplitzOp {
        &self.t
    }

    /// True when the Toeplitz matrix is itself **circulant**
    /// (`c[k] = c[m−k]` for all k) with a power-of-two size — exactly the
    /// case where FFT diagonalisation solves it directly instead of mBCG
    /// (a periodic kernel on a regular wrap-around grid, the SKI `K_UU`
    /// shape where the circulant embedding is exact).
    pub fn is_circulant(&self) -> bool {
        let col = self.t.first_column();
        let m = col.len();
        if !m.is_power_of_two() {
            return false;
        }
        let scale = col.iter().fold(0.0f64, |a, v| a.max(v.abs())).max(1e-300);
        (1..m).all(|k| (col[k] - col[m - k]).abs() <= 1e-12 * scale)
    }
}

impl LinearOp for ToeplitzLinOp {
    fn shape(&self) -> (usize, usize) {
        (self.t.m(), self.t.m())
    }

    fn matmul(&self, m: &Mat) -> Mat {
        self.t.matmul(m)
    }

    fn diag(&self) -> Vec<f64> {
        vec![self.t.diag_value(); self.t.m()]
    }

    fn row(&self, i: usize) -> Vec<f64> {
        let col = self.t.first_column();
        (0..self.t.m()).map(|j| col[i.abs_diff(j)]).collect()
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        self.t.first_column()[i.abs_diff(j)]
    }

    fn solve_hint(&self) -> SolveHint {
        if self.is_circulant() {
            SolveHint::CirculantFft
        } else {
            SolveHint::Iterative
        }
    }

    fn circulant_column(&self) -> Option<Vec<f64>> {
        if self.is_circulant() {
            Some(self.t.first_column().to_vec())
        } else {
            None
        }
    }

    fn dense(&self) -> Mat {
        self.t.to_dense()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = g.t_matmul(&g);
        a.add_diag(0.5);
        a.symmetrize();
        a
    }

    #[test]
    fn kronecker_op_matches_dense_kron() {
        let a = rand_spd(3, 1);
        let b = rand_spd(4, 2);
        let op = KroneckerOp::new(a.clone(), b.clone());
        let want = kron_dense(&a, &b);
        assert!(op.dense().max_abs_diff(&want) < 1e-13);
        let mut rng = Rng::new(3);
        let m = Mat::from_fn(12, 3, |_, _| rng.normal());
        assert!(op.matmul(&m).max_abs_diff(&want.matmul(&m)) < 1e-11);
        for idx in 0..12 {
            let r = op.row(idx);
            for j in 0..12 {
                assert!((r[j] - want.get(idx, j)).abs() < 1e-13);
                assert!((op.entry(idx, j) - want.get(idx, j)).abs() < 1e-13);
            }
            assert!((op.diag()[idx] - want.get(idx, idx)).abs() < 1e-13);
        }
    }

    #[test]
    fn circulant_detection() {
        // wrap-around column c[k] = f(min(k, m−k)) on a pow2 grid → circulant
        let m = 16;
        let col: Vec<f64> = (0..m)
            .map(|k| {
                let d = k.min(m - k) as f64;
                (-0.1 * d * d).exp()
            })
            .collect();
        let op = ToeplitzLinOp::new(col);
        assert!(op.is_circulant());
        assert_eq!(op.solve_hint(), SolveHint::CirculantFft);
        assert_eq!(op.circulant_column().unwrap().len(), m);
        // non-symmetric column → plain Toeplitz, iterative hint
        let decaying: Vec<f64> = (0..m).map(|k| 1.0 / (1.0 + k as f64)).collect();
        let plain = ToeplitzLinOp::new(decaying);
        assert!(!plain.is_circulant());
        assert_eq!(plain.solve_hint(), SolveHint::Iterative);
        assert!(plain.circulant_column().is_none());
        // non-power-of-two size never qualifies
        let odd = ToeplitzLinOp::new(vec![1.0, 0.2, 0.2]);
        assert!(!odd.is_circulant());
    }

    #[test]
    fn toeplitz_op_matches_dense() {
        let mut rng = Rng::new(4);
        let col: Vec<f64> = (0..30).map(|i| rng.normal() / (1.0 + i as f64)).collect();
        let op = ToeplitzLinOp::new(col);
        let want = op.toeplitz().to_dense();
        let m = Mat::from_fn(30, 2, |_, _| rng.normal());
        assert!(op.matmul(&m).max_abs_diff(&want.matmul(&m)) < 1e-9);
        for i in [0usize, 13, 29] {
            assert_eq!(op.row(i), want.row(i).to_vec());
        }
        assert_eq!(op.entry(5, 9), op.entry(9, 5));
    }
}
