//! `K = L·Lᵀ` — the low-rank operator, and the reason SGPR no longer
//! needs a bespoke inference engine.
//!
//! This is the README's "writing a new operator" worked example: the whole
//! model-side contribution of SGPR/SoR (paper §5, Titsias [45]) is the
//! ~40 lines below plus a factor build `A = K_XU·L_uu⁻ᵀ`. Composed as
//! `AddedDiagOp(LowRankOp(A))` the operator
//!
//! - multiplies in O(nkt) (`L(LᵀM)`, never forming `LLᵀ`),
//! - advertises its factor through [`LinearOp::low_rank_factor`], which
//!   flips the solve dispatcher to the **direct Woodbury** path
//!   (`(LLᵀ + σ²I)⁻¹` in O(nk² + k³)) — no CG, no hand-written engine.

use super::{LinearOp, SolveHint};
use crate::tensor::Mat;

/// `L·Lᵀ` for an explicit `n×k` factor.
pub struct LowRankOp {
    l: Mat,
}

impl LowRankOp {
    /// Wrap an `n×k` factor.
    pub fn new(l: Mat) -> Self {
        LowRankOp { l }
    }

    /// The factor `L`.
    pub fn factor(&self) -> &Mat {
        &self.l
    }

    /// Rank `k` of the operator.
    pub fn rank(&self) -> usize {
        self.l.cols()
    }
}

impl LinearOp for LowRankOp {
    fn shape(&self) -> (usize, usize) {
        (self.l.rows(), self.l.rows())
    }

    fn matmul(&self, m: &Mat) -> Mat {
        // L (Lᵀ M): O(nkt), never forms the n×n matrix
        let ltm = self.l.t_matmul(m);
        self.l.matmul(&ltm)
    }

    fn diag(&self) -> Vec<f64> {
        (0..self.l.rows())
            .map(|i| self.l.row(i).iter().map(|v| v * v).sum())
            .collect()
    }

    fn row(&self, i: usize) -> Vec<f64> {
        let li = self.l.row(i);
        (0..self.l.rows())
            .map(|j| {
                let lj = self.l.row(j);
                li.iter().zip(lj.iter()).map(|(a, b)| a * b).sum()
            })
            .collect()
    }

    fn entry(&self, i: usize, j: usize) -> f64 {
        let li = self.l.row(i);
        let lj = self.l.row(j);
        li.iter().zip(lj.iter()).map(|(a, b)| a * b).sum()
    }

    fn solve_hint(&self) -> SolveHint {
        // LLᵀ alone is singular; the hint matters once a diagonal is added
        // (AddedDiagOp promotes it to Woodbury via low_rank_factor)
        SolveHint::Iterative
    }

    fn low_rank_factor(&self) -> Option<&Mat> {
        Some(&self.l)
    }

    fn dense(&self) -> Mat {
        self.l.matmul_t(&self.l)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::op::AddedDiagOp;
    use crate::util::Rng;

    #[test]
    fn matches_explicit_llt() {
        let mut rng = Rng::new(1);
        let l = Mat::from_fn(25, 4, |_, _| rng.normal());
        let op = LowRankOp::new(l.clone());
        let want = l.matmul_t(&l);
        assert!(op.dense().max_abs_diff(&want) < 1e-12);
        let m = Mat::from_fn(25, 3, |_, _| rng.normal());
        assert!(op.matmul(&m).max_abs_diff(&want.matmul(&m)) < 1e-11);
        for (i, d) in op.diag().iter().enumerate() {
            assert!((d - want.get(i, i)).abs() < 1e-12);
        }
        assert_eq!(op.rank(), 4);
        assert!(op.low_rank_factor().is_some());
    }

    #[test]
    fn added_diag_promotes_to_woodbury() {
        let mut rng = Rng::new(2);
        let l = Mat::from_fn(10, 2, |_, _| rng.normal());
        let op = AddedDiagOp::new(LowRankOp::new(l), 0.1);
        assert_eq!(op.solve_hint(), SolveHint::Woodbury);
    }
}
