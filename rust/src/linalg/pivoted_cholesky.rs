//! Rank-k **pivoted Cholesky** decomposition (paper §4.1, Appendix C;
//! Harbrecht et al. [19]).
//!
//! Greedy low-rank approximation `K ≈ L_k L_kᵀ`: at every step, pivot to the
//! largest remaining Schur-complement diagonal entry and peel off one rank-1
//! term. Accesses `K` only through its diagonal and k *rows* — O(ρ(K)·k²)
//! total, where ρ(K) is the row-access cost (O(n) dense, O(n) for SKI,
//! O(nm) for SGPR — App. C.1). The error matrix `E = K − L_k L_kᵀ` is PSD
//! and `Tr(E)` (returned here) bounds ‖E‖₂ — the quantity Lemma 1's
//! condition-number bound runs through.

use crate::tensor::Mat;

/// Result of a rank-k pivoted Cholesky run.
pub struct PivotedCholesky {
    /// `n×k` low-rank factor, rows in *original* index order
    pub l: Mat,
    /// pivot order chosen (first k entries are the selected rows)
    pub pivots: Vec<usize>,
    /// trace of the PSD error matrix `K − L Lᵀ` (≥ 0 in exact arithmetic)
    pub error_trace: f64,
}

/// Compute the rank-`max_rank` pivoted Cholesky decomposition of the matrix
/// whose diagonal is `diag` and whose `i`-th row is produced by `row(i)`.
///
/// Stops early if the Schur trace drops below `tol` (pass 0.0 to always run
/// to `max_rank`).
pub fn pivoted_cholesky(
    diag: &[f64],
    row: impl Fn(usize) -> Vec<f64>,
    max_rank: usize,
    tol: f64,
) -> PivotedCholesky {
    let n = diag.len();
    let k = max_rank.min(n);
    let mut d = diag.to_vec(); // Schur-complement diagonal
    let mut perm: Vec<usize> = (0..n).collect();
    // L stored row-major n×k, original ordering
    let mut l = Mat::zeros(n, k);
    let mut rank = 0usize;

    for m in 0..k {
        // pivot: largest remaining diagonal entry
        let (argmax, dmax) = perm[m..]
            .iter()
            .map(|&i| (i, d[i]))
            .fold((perm[m], f64::NEG_INFINITY), |acc, (i, v)| {
                if v > acc.1 {
                    (i, v)
                } else {
                    acc
                }
            });
        if dmax <= tol.max(0.0) || !dmax.is_finite() {
            break;
        }
        // swap into position m
        let pos = perm[m..].iter().position(|&i| i == argmax).unwrap() + m;
        perm.swap(m, pos);
        let pm = perm[m];

        let gamma = dmax.sqrt();
        l.set(pm, m, gamma);
        let krow = row(pm);
        debug_assert_eq!(krow.len(), n);
        for &pi in &perm[m + 1..] {
            // v = (K[pm, pi] − Σ_{j<m} L[pm,j] L[pi,j]) / γ
            let mut v = krow[pi];
            let lrow_pm = l.row(pm);
            let lrow_pi = l.row(pi);
            for j in 0..m {
                v -= lrow_pm[j] * lrow_pi[j];
            }
            v /= gamma;
            l.set(pi, m, v);
            d[pi] -= v * v;
        }
        d[pm] = 0.0;
        rank = m + 1;
    }

    let error_trace: f64 = perm[rank..].iter().map(|&i| d[i].max(0.0)).sum();
    let l = if rank < k { l.cols_range(0, rank) } else { l };
    PivotedCholesky {
        l,
        pivots: perm,
        error_trace,
    }
}

/// Convenience wrapper over a composed [`crate::linalg::op::LinearOp`]:
/// factor the operator itself (callers wanting the paper's *noise-free*
/// preconditioner pass the operator's `noise_split` inner part — or use
/// [`crate::linalg::op::build_preconditioner`], which does exactly that).
pub fn pivoted_cholesky_op(
    op: &dyn crate::linalg::op::LinearOp,
    max_rank: usize,
    tol: f64,
) -> PivotedCholesky {
    pivoted_cholesky(&op.diag(), |i| op.row(i), max_rank, tol)
}

/// Convenience wrapper over a dense matrix.
pub fn pivoted_cholesky_dense(k_mat: &Mat, max_rank: usize, tol: f64) -> PivotedCholesky {
    let n = k_mat.rows();
    let diag: Vec<f64> = (0..n).map(|i| k_mat.get(i, i)).collect();
    pivoted_cholesky(&diag, |i| k_mat.row(i).to_vec(), max_rank, tol)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rbf_kernel(n: usize, ls: f64, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let xs: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let k = Mat::from_fn(n, n, |i, j| {
            let d = xs[i] - xs[j];
            (-d * d / (2.0 * ls * ls)).exp()
        });
        (k, xs)
    }

    #[test]
    fn full_rank_reconstructs_exactly() {
        let n = 20;
        let mut rng = Rng::new(1);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = g.t_matmul(&g);
        a.add_diag(1.0);
        let pc = pivoted_cholesky_dense(&a, n, 0.0);
        let recon = pc.l.matmul_t(&pc.l);
        assert!(recon.max_abs_diff(&a) < 1e-8);
        assert!(pc.error_trace.abs() < 1e-8);
    }

    #[test]
    fn error_trace_decreases_monotonically_in_rank() {
        let (k, _) = rbf_kernel(60, 0.2, 2);
        let mut prev = f64::INFINITY;
        for rank in [1, 2, 4, 8, 16] {
            let pc = pivoted_cholesky_dense(&k, rank, 0.0);
            assert!(
                pc.error_trace <= prev + 1e-12,
                "rank {rank}: {} > {prev}",
                pc.error_trace
            );
            prev = pc.error_trace;
        }
    }

    #[test]
    fn rbf_error_decays_exponentially() {
        // Lemma 2/3: for (univariate) RBF kernels Tr(E) ≲ n exp(-bk)
        let (k, _) = rbf_kernel(100, 0.3, 3);
        let e2 = pivoted_cholesky_dense(&k, 2, 0.0).error_trace;
        let e6 = pivoted_cholesky_dense(&k, 6, 0.0).error_trace;
        let e10 = pivoted_cholesky_dense(&k, 10, 0.0).error_trace;
        assert!(e6 < e2 * 1e-1, "e2={e2} e6={e6}");
        assert!(e10 < e6, "e6={e6} e10={e10}");
        assert!(e10 < 1e-6 * 100.0, "e10={e10}");
    }

    #[test]
    fn error_matrix_is_psd() {
        // E = K - L Lᵀ must be PSD: check via jittered Cholesky success
        let (k, _) = rbf_kernel(40, 0.25, 4);
        let pc = pivoted_cholesky_dense(&k, 5, 0.0);
        let mut e = k.sub(&pc.l.matmul_t(&pc.l));
        // tiny jitter to absorb roundoff
        e.add_diag(1e-10);
        assert!(crate::linalg::cholesky::Cholesky::new(&e).is_ok());
    }

    #[test]
    fn error_trace_matches_actual_trace() {
        let (k, _) = rbf_kernel(30, 0.4, 5);
        let pc = pivoted_cholesky_dense(&k, 4, 0.0);
        let recon = pc.l.matmul_t(&pc.l);
        let actual: f64 = (0..30).map(|i| k.get(i, i) - recon.get(i, i)).sum();
        assert!((pc.error_trace - actual).abs() < 1e-9);
    }

    #[test]
    fn pivots_pick_largest_diagonal_first() {
        // diagonal matrix: pivot order must be descending diagonal
        let n = 8;
        let vals = [3.0, 9.0, 1.0, 7.0, 2.0, 8.0, 5.0, 4.0];
        let k = Mat::from_fn(n, n, |i, j| if i == j { vals[i] } else { 0.0 });
        let pc = pivoted_cholesky_dense(&k, 3, 0.0);
        assert_eq!(&pc.pivots[..3], &[1, 5, 3]);
    }

    #[test]
    fn early_stop_on_tolerance() {
        // rank-2 matrix: Schur trace hits ~0 after 2 steps
        let n = 25;
        let mut rng = Rng::new(6);
        let g = Mat::from_fn(n, 2, |_, _| rng.normal());
        let k = g.matmul_t(&g);
        let pc = pivoted_cholesky_dense(&k, 10, 1e-10);
        assert!(pc.l.cols() <= 3, "rank found {}", pc.l.cols());
        let recon = pc.l.matmul_t(&pc.l);
        assert!(recon.max_abs_diff(&k) < 1e-6);
    }

    #[test]
    fn blackbox_row_access_matches_dense() {
        let (k, _) = rbf_kernel(35, 0.3, 7);
        let diag: Vec<f64> = (0..35).map(|i| k.get(i, i)).collect();
        let via_rows = pivoted_cholesky(&diag, |i| k.row(i).to_vec(), 6, 0.0);
        let via_dense = pivoted_cholesky_dense(&k, 6, 0.0);
        assert!(via_rows.l.max_abs_diff(&via_dense.l) < 1e-12);
    }
}
