//! In-place iterative radix-2 complex FFT (Cooley–Tukey), built from scratch
//! as the substrate for fast Toeplitz matrix-vector products (KISS-GP's
//! `K_UU` structure — §5 of the paper: MVMs with a Toeplitz `K_UU` in
//! O(m log m)).

use std::f64::consts::PI;

/// Complex number (the vendored crate set has no `num-complex`).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Cplx {
    pub re: f64,
    pub im: f64,
}

impl Cplx {
    pub const ZERO: Cplx = Cplx { re: 0.0, im: 0.0 };

    #[inline]
    pub fn new(re: f64, im: f64) -> Cplx {
        Cplx { re, im }
    }

    #[inline]
    pub fn mul(self, o: Cplx) -> Cplx {
        Cplx {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }

    #[inline]
    pub fn add(self, o: Cplx) -> Cplx {
        Cplx {
            re: self.re + o.re,
            im: self.im + o.im,
        }
    }

    #[inline]
    pub fn sub(self, o: Cplx) -> Cplx {
        Cplx {
            re: self.re - o.re,
            im: self.im - o.im,
        }
    }
}

/// next power of two ≥ n
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// In-place FFT (forward if `inverse=false`). Length must be a power of two.
/// The inverse transform includes the 1/N normalisation.
pub fn fft_inplace(a: &mut [Cplx], inverse: bool) {
    let n = a.len();
    assert!(n.is_power_of_two(), "fft length must be a power of two");
    if n <= 1 {
        return;
    }
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            a.swap(i, j);
        }
    }
    // butterflies
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2usize;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let wlen = Cplx::new(ang.cos(), ang.sin());
        let half = len / 2;
        let mut i = 0;
        while i < n {
            let mut w = Cplx::new(1.0, 0.0);
            for k in 0..half {
                let u = a[i + k];
                let v = a[i + k + half].mul(w);
                a[i + k] = u.add(v);
                a[i + k + half] = u.sub(v);
                w = w.mul(wlen);
            }
            i += len;
        }
        len <<= 1;
    }
    if inverse {
        let inv_n = 1.0 / n as f64;
        for v in a.iter_mut() {
            v.re *= inv_n;
            v.im *= inv_n;
        }
    }
}

/// Real convolution-style helper: FFT of a real signal (zero-padded copy).
pub fn fft_real(x: &[f64], len: usize) -> Vec<Cplx> {
    assert!(len.is_power_of_two() && len >= x.len());
    let mut buf = vec![Cplx::ZERO; len];
    for (i, &v) in x.iter().enumerate() {
        buf[i] = Cplx::new(v, 0.0);
    }
    fft_inplace(&mut buf, false);
    buf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn dft_naive(x: &[Cplx], inverse: bool) -> Vec<Cplx> {
        let n = x.len();
        let sign = if inverse { 1.0 } else { -1.0 };
        let mut out = vec![Cplx::ZERO; n];
        for (k, o) in out.iter_mut().enumerate() {
            for (t, &v) in x.iter().enumerate() {
                let ang = sign * 2.0 * PI * (k * t) as f64 / n as f64;
                *o = o.add(v.mul(Cplx::new(ang.cos(), ang.sin())));
            }
        }
        if inverse {
            for o in out.iter_mut() {
                o.re /= n as f64;
                o.im /= n as f64;
            }
        }
        out
    }

    #[test]
    fn matches_naive_dft() {
        let mut rng = Rng::new(1);
        for &n in &[1usize, 2, 4, 8, 16, 64] {
            let x: Vec<Cplx> = (0..n).map(|_| Cplx::new(rng.normal(), rng.normal())).collect();
            let mut got = x.clone();
            fft_inplace(&mut got, false);
            let want = dft_naive(&x, false);
            for i in 0..n {
                assert!((got[i].re - want[i].re).abs() < 1e-9, "n={n} i={i}");
                assert!((got[i].im - want[i].im).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn roundtrip_identity() {
        let mut rng = Rng::new(2);
        let n = 128;
        let x: Vec<Cplx> = (0..n).map(|_| Cplx::new(rng.normal(), rng.normal())).collect();
        let mut buf = x.clone();
        fft_inplace(&mut buf, false);
        fft_inplace(&mut buf, true);
        for i in 0..n {
            assert!((buf[i].re - x[i].re).abs() < 1e-10);
            assert!((buf[i].im - x[i].im).abs() < 1e-10);
        }
    }

    #[test]
    fn parseval() {
        let mut rng = Rng::new(3);
        let n = 64;
        let x: Vec<Cplx> = (0..n).map(|_| Cplx::new(rng.normal(), 0.0)).collect();
        let mut f = x.clone();
        fft_inplace(&mut f, false);
        let e_time: f64 = x.iter().map(|c| c.re * c.re + c.im * c.im).sum();
        let e_freq: f64 = f.iter().map(|c| c.re * c.re + c.im * c.im).sum::<f64>() / n as f64;
        assert!((e_time - e_freq).abs() < 1e-9);
    }

    #[test]
    fn convolution_via_fft_matches_direct() {
        let mut rng = Rng::new(4);
        let a: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..7).map(|_| rng.normal()).collect();
        let len = next_pow2(a.len() + b.len() - 1);
        let mut fa = fft_real(&a, len);
        let fb = fft_real(&b, len);
        for i in 0..len {
            fa[i] = fa[i].mul(fb[i]);
        }
        fft_inplace(&mut fa, true);
        for k in 0..(a.len() + b.len() - 1) {
            let mut direct = 0.0;
            for i in 0..a.len() {
                if k >= i && k - i < b.len() {
                    direct += a[i] * b[k - i];
                }
            }
            assert!((fa[k].re - direct).abs() < 1e-9, "k={k}");
        }
    }
}
