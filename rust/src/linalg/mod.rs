//! Numerical core of the BBMM inference engine.
//!
//! - [`cholesky`] — dense Cholesky factorization: the paper's O(n³) baseline
//!   inference engine (GPFlow-equivalent on this testbed).
//! - [`cg`] — standard preconditioned conjugate gradients (Alg. 1).
//! - [`mbcg`](mod@mbcg) — **the paper's contribution**: modified batched CG (Alg. 2)
//!   returning multi-RHS solves *and* Lanczos tridiagonal matrices recovered
//!   from the CG coefficients (App. A, Saad §6.7.3).
//! - [`lanczos`] — explicit Lanczos tridiagonalization, used by the Dong
//!   et al. [13] baseline engine.
//! - [`tridiag`] — symmetric tridiagonal eigensolver (implicit QL) used for
//!   the stochastic-Lanczos-quadrature log-determinant `e₁ᵀ log(T̃) e₁`.
//! - [`pivoted_cholesky`](mod@pivoted_cholesky) — rank-k pivoted Cholesky (App. C) from blackbox
//!   row access.
//! - [`preconditioner`] — `P̂ = L_k L_kᵀ + σ²I` with O(nk²) Woodbury solves
//!   and exact log-determinant (§4.1).
//! - [`trace`] — Hutchinson stochastic trace estimation (eq. 4).
//! - [`fft`] / [`toeplitz`] — substrate for KISS-GP's structured `K_UU`.
//! - [`op`] — the composable **`LinearOp` operator algebra** every model is
//!   expressed in, plus the solve-strategy dispatcher (direct Cholesky /
//!   Woodbury vs iterative mBCG, picked from operator structure).

pub mod cg;
pub mod cholesky;
pub mod fft;
pub mod kronecker;
pub mod lanczos;
pub mod love;
pub mod mbcg;
pub mod op;
pub mod pivoted_cholesky;
pub mod preconditioner;
pub mod toeplitz;
pub mod trace;
pub mod tridiag;

pub use cg::{pcg, PcgResult};
pub use cholesky::Cholesky;
pub use kronecker::{kron_dense, kron_matmul, kron_matvec};
pub use lanczos::lanczos_tridiag;
pub use love::LoveFactors;
pub use mbcg::{mbcg, mbcg_batch, mbcg_op, MbcgOptions, MbcgResult, MbcgWorkspace, TriDiag};
pub use op::{BatchOp, LinearOp, SolveHint, SolveOptions, SolvePlanCache};
pub use pivoted_cholesky::{pivoted_cholesky, pivoted_cholesky_op, PivotedCholesky};
pub use preconditioner::{IdentityPrecond, PartialCholPrecond, Preconditioner};
pub use toeplitz::ToeplitzOp;
pub use trace::hutchinson_trace;
pub use tridiag::SymTridiagEig;
