//! **LOVE** — LanczOs Variance Estimates (Pleiss et al. 2018), the
//! constant-time predictive-(co)variance factor behind the posterior
//! cache.
//!
//! After training, every predictive variance needs the quadratic form
//! `k_*ᵀ K̂⁻¹ k_*` — paying a fresh mBCG solve per query block. LOVE
//! instead caches a rank-r root of `K̂⁻¹` once per hyperparameter setting:
//! run r Lanczos iterations ([`crate::linalg::lanczos`]) against the
//! **noise-free** part of the operator (`K̂ = K + σ²I` via
//! [`LinearOp::noise_split`]), giving `K ≈ Q T Qᵀ`; with `T = L·Lᵀ` and
//! `W = Q·L` the Woodbury identity turns the whole inverse into a rank-r
//! capacitance solve:
//!
//! ```text
//! K̂⁻¹ = (W·Wᵀ + σ²I)⁻¹ = (I − W·C⁻¹·Wᵀ) / σ²,   C = σ²I + WᵀW
//! k_*ᵀ K̂⁻¹ k_* = (‖k_*‖² − ‖R·k_*‖²) / σ²,       R = M⁻¹Wᵀ, C = M·Mᵀ
//! ```
//!
//! so the cached factor is the single r×n matrix `R` and every variance
//! query is one skinny GEMM — O(n·r) instead of O(n²·iters). Running
//! Lanczos on `K` rather than `K̂` is what makes the factor *exact* once
//! the Krylov space captures `K`'s effective rank: the truncated
//! directions really do carry `K ≈ 0`, and the σ²I part is handled
//! algebraically, not iteratively. Operators with no `A + σ²I` split fall
//! back to the direct Lanczos inverse root `R = L_T⁻¹Qᵀ` with
//! `k_*ᵀK̂⁻¹k_* ≈ ‖R·k_*‖²`.

use crate::linalg::cholesky::Cholesky;
use crate::linalg::lanczos::lanczos_tridiag;
use crate::linalg::op::LinearOp;
use crate::tensor::Mat;

/// Rank-r root factor of `K̂⁻¹`: the cached state every LOVE variance /
/// posterior-covariance query is answered from. See the module docs for
/// the two modes (Woodbury over `noise_split`, direct Lanczos fallback).
pub struct LoveFactors {
    /// `R` (r×n). Woodbury mode: `quad(v) = (‖v‖² − ‖R·v‖²)/σ²`; direct
    /// mode: `quad(v) = ‖R·v‖²`.
    root: Mat,
    /// σ² of the operator's added diagonal; `0.0` selects direct mode.
    sigma2: f64,
}

impl LoveFactors {
    /// Build the rank-`rank` factor for `op = K + σ²I` using `probe` as
    /// the Lanczos start vector. The achieved rank may be lower: Lanczos
    /// truncates when the Krylov space hits an invariant subspace of `K`,
    /// which for kernel matrices means the neglected directions carry
    /// negligible covariance (the factor only gets *more* exact).
    pub fn build_op(op: &dyn LinearOp, probe: &[f64], rank: usize) -> LoveFactors {
        let n = op.n();
        assert_eq!(probe.len(), n, "LOVE probe length must match operator size");
        assert!(rank > 0, "LOVE rank must be positive");
        match op.noise_split() {
            Some((inner, sigma2)) if sigma2 > 0.0 => {
                let (t, q) = lanczos_tridiag(
                    |v| {
                        let out = inner.matmul(&Mat::col_from_slice(v));
                        out.col(0)
                    },
                    probe,
                    rank,
                );
                let r = t.n();
                // T is PSD up to roundoff (Lanczos on a PSD K); the jitter
                // schedule absorbs slightly-negative trailing Ritz values.
                let lt = Cholesky::new_with_jitter(&t.to_dense())
                    .expect("LOVE: Lanczos tridiagonal not factorizable");
                let w = q.matmul(lt.l()); // n×r, K ≈ W·Wᵀ
                let mut c = w.t_matmul(&w); // capacitance σ²I + WᵀW
                c.add_diag(sigma2);
                let m = Cholesky::new_with_jitter(&c)
                    .expect("LOVE: capacitance not positive definite");
                // R = M⁻¹Wᵀ, one forward substitution per training point
                let mut root = Mat::zeros(r, n);
                for j in 0..n {
                    let col = m.forward_solve(w.row(j));
                    for (i, v) in col.iter().enumerate() {
                        root.set(i, j, *v);
                    }
                }
                LoveFactors { root, sigma2 }
            }
            _ => {
                // no noise split: direct Lanczos inverse root on K̂ itself
                let (t, q) = lanczos_tridiag(
                    |v| {
                        let out = op.matmul(&Mat::col_from_slice(v));
                        out.col(0)
                    },
                    probe,
                    rank,
                );
                let r = t.n();
                let lt = Cholesky::new_with_jitter(&t.to_dense())
                    .expect("LOVE: Lanczos tridiagonal not factorizable");
                let mut root = Mat::zeros(r, n);
                for j in 0..n {
                    let col = lt.forward_solve(q.row(j));
                    for (i, v) in col.iter().enumerate() {
                        root.set(i, j, *v);
                    }
                }
                LoveFactors { root, sigma2: 0.0 }
            }
        }
    }

    /// Achieved rank r (≤ the requested rank when Lanczos truncated).
    pub fn rank(&self) -> usize {
        self.root.rows()
    }

    /// Training-set size n.
    pub fn n(&self) -> usize {
        self.root.cols()
    }

    /// True when the factor runs the Woodbury (noise-split) mode.
    pub fn is_woodbury(&self) -> bool {
        self.sigma2 > 0.0
    }

    /// The cached r×n root `R`.
    pub fn root(&self) -> &Mat {
        &self.root
    }

    /// Quadratic forms `k_jᵀ K̂⁻¹ k_j` for every row `k_jᵀ` of `k_star`
    /// (s×n) — ONE skinny GEMM `R·K_*ᵀ` for the whole block.
    pub fn quad_diag(&self, k_star: &Mat) -> Vec<f64> {
        assert_eq!(k_star.cols(), self.n(), "quad_diag: k_star width mismatch");
        let v = self.root.matmul_t(k_star); // r×s
        let s = k_star.rows();
        let r = self.rank();
        let mut out = vec![0.0; s];
        for (j, q) in out.iter_mut().enumerate() {
            let mut rq = 0.0;
            for i in 0..r {
                let e = v.get(i, j);
                rq += e * e;
            }
            if self.sigma2 > 0.0 {
                let krow = k_star.row(j);
                let norm2: f64 = krow.iter().map(|x| x * x).sum();
                // ‖R·k‖ ≤ ‖k‖ holds algebraically; clamp the roundoff
                *q = ((norm2 - rq) / self.sigma2).max(0.0);
            } else {
                *q = rq;
            }
        }
        out
    }

    /// Full cross quadratic block `A K̂⁻¹ Bᵀ` for row blocks `a` (s_a×n)
    /// and `b` (s_b×n) — the posterior-covariance building block.
    pub fn quad_cross(&self, a: &Mat, b: &Mat) -> Mat {
        assert_eq!(a.cols(), self.n(), "quad_cross: a width mismatch");
        assert_eq!(b.cols(), self.n(), "quad_cross: b width mismatch");
        let va = self.root.matmul_t(a); // r×s_a
        let vb = self.root.matmul_t(b); // r×s_b
        let rr = va.t_matmul(&vb); // s_a×s_b
        if self.sigma2 > 0.0 {
            let ab = a.matmul_t(b);
            Mat::from_fn(a.rows(), b.rows(), |i, j| {
                (ab.get(i, j) - rr.get(i, j)) / self.sigma2
            })
        } else {
            rr
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{DenseKernelOp, Rbf};
    use crate::linalg::op::AddedDiagOp;
    use crate::linalg::op::LowRankOp;
    use crate::util::Rng;

    fn kernel_op(n: usize, seed: u64, noise: f64) -> DenseKernelOp {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), noise)
    }

    /// dense reference quad `k_jᵀ K̂⁻¹ k_j`
    fn reference_quads(op: &dyn LinearOp, k_star: &Mat) -> Vec<f64> {
        let ch = Cholesky::new_with_jitter(&op.dense()).unwrap();
        let solved = ch.solve_mat(&k_star.transpose()); // n×s
        (0..k_star.rows())
            .map(|j| {
                let krow = k_star.row(j);
                (0..k_star.cols()).map(|i| krow[i] * solved.get(i, j)).sum()
            })
            .collect()
    }

    #[test]
    fn full_rank_woodbury_factor_is_exact() {
        let n = 35;
        let op = kernel_op(n, 1, 0.1);
        let mut rng = Rng::new(2);
        let probe = rng.normal_vec(n);
        let f = LoveFactors::build_op(&op, &probe, n);
        assert!(f.is_woodbury());
        let k_star = Mat::from_fn(6, n, |_, _| rng.uniform_in(-1.0, 1.0));
        let got = f.quad_diag(&k_star);
        let want = reference_quads(&op, &k_star);
        for j in 0..6 {
            assert!(
                (got[j] - want[j]).abs() <= 1e-8 * want[j].abs().max(1e-12),
                "quad {j}: {} vs {}",
                got[j],
                want[j]
            );
        }
    }

    #[test]
    fn low_rank_factor_converges_with_rank() {
        let n = 120;
        let op = kernel_op(n, 3, 0.1);
        let mut rng = Rng::new(4);
        let probe = rng.normal_vec(n);
        let k_star = Mat::from_fn(5, n, |_, _| rng.uniform_in(-1.0, 1.0));
        let want = reference_quads(&op, &k_star);
        let err = |rank: usize| {
            let f = LoveFactors::build_op(&op, &probe, rank);
            let got = f.quad_diag(&k_star);
            (0..5)
                .map(|j| ((got[j] - want[j]) / want[j]).abs())
                .fold(0.0f64, f64::max)
        };
        let coarse = err(6);
        let fine = err(60);
        assert!(fine <= coarse + 1e-12, "rank must not hurt: {coarse} vs {fine}");
        assert!(fine < 1e-6, "rank-60 factor should be near-exact: {fine}");
    }

    #[test]
    fn lanczos_truncation_on_low_rank_operators_stays_exact() {
        // SGPR-shaped operator: rank-m K forces Lanczos truncation at ~m;
        // the Woodbury mode must stay exact there (the whole point of
        // factoring the noise out algebraically)
        let n = 40;
        let m = 12;
        let mut rng = Rng::new(5);
        let a = Mat::from_fn(n, m, |_, _| rng.normal());
        let op = AddedDiagOp::new(LowRankOp::new(a), 0.2);
        let probe = rng.normal_vec(n);
        let f = LoveFactors::build_op(&op, &probe, n);
        assert!(f.rank() <= m + 1, "Lanczos should truncate near rank {m}, got {}", f.rank());
        let k_star = Mat::from_fn(4, n, |_, _| rng.normal());
        let got = f.quad_diag(&k_star);
        let want = reference_quads(&op, &k_star);
        for j in 0..4 {
            assert!(
                (got[j] - want[j]).abs() <= 1e-7 * want[j].abs().max(1e-12),
                "quad {j}: {} vs {}",
                got[j],
                want[j]
            );
        }
    }

    #[test]
    fn quad_cross_diagonal_matches_quad_diag() {
        let n = 30;
        let op = kernel_op(n, 6, 0.05);
        let mut rng = Rng::new(7);
        let probe = rng.normal_vec(n);
        let f = LoveFactors::build_op(&op, &probe, n);
        let k_star = Mat::from_fn(5, n, |_, _| rng.uniform_in(-1.0, 1.0));
        let diag = f.quad_diag(&k_star);
        let full = f.quad_cross(&k_star, &k_star);
        for j in 0..5 {
            assert!((full.get(j, j) - diag[j]).abs() < 1e-9, "entry {j}");
        }
        // symmetry of the cross block
        for i in 0..5 {
            for j in 0..5 {
                assert!((full.get(i, j) - full.get(j, i)).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn direct_mode_handles_unsplit_operators() {
        // a dense operator with no AddedDiag wrapper exercises the
        // fallback: full-rank direct Lanczos inverse root
        use crate::linalg::op::DenseOp;
        let n = 25;
        let mut rng = Rng::new(8);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut k = g.t_matmul(&g);
        k.add_diag(n as f64 * 0.5);
        let op = DenseOp::new(k);
        let probe = rng.normal_vec(n);
        let f = LoveFactors::build_op(&op, &probe, n);
        assert!(!f.is_woodbury());
        let k_star = Mat::from_fn(3, n, |_, _| rng.normal());
        let got = f.quad_diag(&k_star);
        let want = reference_quads(&op, &k_star);
        for j in 0..3 {
            assert!(
                (got[j] - want[j]).abs() <= 1e-6 * want[j].abs().max(1e-12),
                "quad {j}: {} vs {}",
                got[j],
                want[j]
            );
        }
    }
}
