//! **mBCG** — modified batched conjugate gradients (paper §4, Algorithm 2).
//!
//! The core contribution of the paper: a single batched CG call that
//!
//! 1. solves `K̂⁻¹ [b₁ … b_s]` against all right-hand sides simultaneously,
//!    turning the per-iteration work into one big matrix-matrix multiply
//!    (`mmm_A`) plus O(ns) vector work, and
//! 2. recovers, for each RHS, the partial Lanczos tridiagonalization `T̃ᵢ`
//!    of the (preconditioned) operator from the CG coefficients
//!    (Observation 3 / Saad §6.7.3):
//!    `T[j,j] = 1/α_j + β_{j−1}/α_{j−1}`, `T[j,j+1] = √β_j / α_j`.
//!
//! The tridiagonal matrices feed the stochastic-Lanczos-quadrature
//! log-determinant estimate `e₁ᵀ log(T̃ᵢ) e₁` (eq. 6) without ever running
//! the (storage-hungry, numerically fragile) Lanczos algorithm.

use crate::tensor::{Mat, Scalar};
use crate::util::par;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A symmetric tridiagonal matrix stored by diagonals (always f64 — the
/// coefficients are accumulated in f64 regardless of solve precision).
#[derive(Debug, Clone, PartialEq)]
pub struct TriDiag {
    pub diag: Vec<f64>,
    pub offdiag: Vec<f64>,
}

impl TriDiag {
    pub fn n(&self) -> usize {
        self.diag.len()
    }

    /// Dense form (tests / small-p paths).
    pub fn to_dense(&self) -> Mat {
        let p = self.n();
        let mut t = Mat::zeros(p, p);
        for i in 0..p {
            t.set(i, i, self.diag[i]);
            if i + 1 < p {
                t.set(i, i + 1, self.offdiag[i]);
                t.set(i + 1, i, self.offdiag[i]);
            }
        }
        t
    }
}

/// Options for [`mbcg`].
#[derive(Debug, Clone, Copy)]
pub struct MbcgOptions {
    /// maximum CG iterations `p`
    pub max_iters: usize,
    /// relative-residual stopping tolerance (applied per column; the batch
    /// stops when every column has converged)
    pub tol: f64,
    /// number of leading columns that are "solve-only" (no tridiagonal
    /// needed) — the paper passes `[y z₁ … z_t]` and only needs T̃ for the
    /// probe columns.
    pub n_solve_only: usize,
}

impl Default for MbcgOptions {
    fn default() -> Self {
        MbcgOptions {
            max_iters: 20, // the paper's experiment default (§6)
            tol: 1e-10,
            n_solve_only: 0,
        }
    }
}

/// Result of an mBCG call.
pub struct MbcgResult<T: Scalar = f64> {
    /// `A⁻¹ B` approximations, one column per RHS
    pub solves: Mat<T>,
    /// Lanczos tridiagonal matrices for columns `n_solve_only..`, in order
    pub tridiags: Vec<TriDiag>,
    /// iterations performed (shared by all columns of this system; in
    /// [`mbcg_batch`] each system reports its own count)
    pub iterations: usize,
    /// per-column relative residual at exit
    pub final_residuals: Vec<f64>,
    /// mean relative residual after each iteration (diagnostics / Fig. 4)
    pub residual_history: Vec<f64>,
}

/// Per-RHS-block CG state machine — the shared core of [`mbcg`] (one
/// system) and [`mbcg_batch`] (b systems through one iteration loop).
/// Holds solutions, residuals, search directions, and the per-column α/β
/// streams the Lanczos tridiagonals are recovered from; converged columns
/// freeze exactly as in Algorithm 2.
struct CgSystem<T: Scalar> {
    u: Mat<T>,
    r: Mat<T>,
    d: Mat<T>,
    bnorms: Vec<f64>,
    rz_old: Vec<f64>,
    alphas: Vec<Vec<f64>>,
    betas: Vec<Vec<f64>>,
    converged: Vec<bool>,
    final_res: Vec<f64>,
    history: Vec<f64>,
    iterations: usize,
}

impl<T: Scalar> CgSystem<T> {
    /// Initialise from the RHS block and its preconditioned copy
    /// `z0 = P̂⁻¹·b` (residual of the zero initial guess). `max_iters`
    /// pre-sizes the α/β streams and the residual history so the
    /// iteration loop never grows a vector.
    fn new(b: &Mat<T>, z0: Mat<T>, max_iters: usize) -> Self {
        let s = b.cols();
        let bnorms: Vec<f64> = (0..s).map(|c| col_norm(b, c).max(1e-300)).collect();
        let r = b.clone();
        let rz_old: Vec<f64> = (0..s).map(|c| col_dot(&r, &z0, c)).collect();
        let d = z0; // the initial search direction IS z₀ — no copy needed
        let mut converged = vec![false; s];
        // all-converged fast path for zero RHS
        for c in 0..s {
            if col_norm(b, c) == 0.0 {
                converged[c] = true;
            }
        }
        CgSystem {
            u: Mat::<T>::zeros(b.rows(), s),
            r,
            d,
            bnorms,
            rz_old,
            // NOT vec![Vec::with_capacity(..); s] — Vec::clone does not
            // preserve capacity, which would put growth reallocations
            // back inside the iteration loop
            alphas: (0..s).map(|_| Vec::with_capacity(max_iters)).collect(),
            betas: (0..s).map(|_| Vec::with_capacity(max_iters)).collect(),
            converged,
            final_res: vec![0.0f64; s],
            history: Vec::with_capacity(max_iters),
            iterations: 0,
        }
    }

    /// True once every column has converged (the system is frozen).
    fn done(&self) -> bool {
        self.converged.iter().all(|&c| c)
    }

    /// α-step: absorb the operator product `v = A·d` — update solutions,
    /// residuals, per-column convergence, and the residual history.
    fn absorb_product(&mut self, v: &Mat<T>, tol: f64) {
        let n = self.u.rows();
        let s = self.u.cols();
        self.iterations += 1;
        let mut mean_res = 0.0;
        for c in 0..s {
            if self.converged[c] {
                mean_res += self.final_res[c];
                continue;
            }
            let dv = col_dot(&self.d, v, c);
            if dv.abs() < 1e-300 || !dv.is_finite() {
                self.converged[c] = true;
                continue;
            }
            let alpha = self.rz_old[c] / dv;
            self.alphas[c].push(alpha);
            // u_c += α d_c ; r_c -= α v_c
            for i in 0..n {
                let uval = self.u.get(i, c).to_f64() + alpha * self.d.get(i, c).to_f64();
                self.u.set(i, c, T::from_f64(uval));
                let rval = self.r.get(i, c).to_f64() - alpha * v.get(i, c).to_f64();
                self.r.set(i, c, T::from_f64(rval));
            }
            let rel = col_norm(&self.r, c) / self.bnorms[c];
            self.final_res[c] = rel;
            mean_res += rel;
            if rel < tol {
                self.converged[c] = true;
            }
        }
        self.history.push(mean_res / s as f64);
    }

    /// β-step: refresh search directions from the freshly preconditioned
    /// residuals `z = P̂⁻¹·r`.
    fn refresh_directions(&mut self, z: &Mat<T>) {
        let n = self.u.rows();
        let s = self.u.cols();
        for c in 0..s {
            if self.converged[c] {
                continue;
            }
            let rz_new = col_dot(&self.r, z, c);
            let beta = rz_new / self.rz_old[c];
            self.betas[c].push(beta);
            self.rz_old[c] = rz_new;
            // d_c = z_c + β d_c
            for i in 0..n {
                let dval = z.get(i, c).to_f64() + beta * self.d.get(i, c).to_f64();
                self.d.set(i, c, T::from_f64(dval));
            }
        }
    }

    /// Finish: recover tridiagonal matrices from the CG coefficients
    /// (Obs. 3) for columns `n_solve_only..` and package the result.
    fn into_result(self, n_solve_only: usize) -> MbcgResult<T> {
        let s = self.u.cols();
        let skip = n_solve_only.min(s);
        let mut tridiags = Vec::with_capacity(s - skip);
        for c in skip..s {
            tridiags.push(tridiag_from_coeffs(&self.alphas[c], &self.betas[c]));
        }
        MbcgResult {
            solves: self.u,
            tridiags,
            iterations: self.iterations,
            final_residuals: self.final_res,
            residual_history: self.history,
        }
    }
}

/// Modified batched preconditioned CG (Algorithm 2).
///
/// * `mmm_a` — the blackbox: multiplies the (implicit) SPD matrix `A` by an
///   `n×s` matrix. This is the only way `A` is accessed.
/// * `b` — `n×s` right-hand sides `[b₁ … b_s]`.
/// * `precond` — applies `P̂⁻¹` to an `n×s` matrix (identity if `None`-like;
///   see [`crate::linalg::preconditioner`]).
///
/// Converged columns are frozen: their solution stops updating and their
/// α/β streams stop extending, exactly as if that column's CG had returned.
pub fn mbcg<T: Scalar>(
    mmm_a: impl Fn(&Mat<T>) -> Mat<T>,
    b: &Mat<T>,
    precond: impl Fn(&Mat<T>) -> Mat<T>,
    opts: &MbcgOptions,
) -> MbcgResult<T> {
    assert!(opts.n_solve_only <= b.cols());
    let mut sys = CgSystem::new(b, precond(b), opts.max_iters);
    for _ in 0..opts.max_iters {
        if sys.done() {
            break;
        }
        let v = mmm_a(&sys.d);
        sys.absorb_product(&v, opts.tol);
        if sys.done() {
            break;
        }
        let z = precond(&sys.r);
        sys.refresh_directions(&z);
    }
    sys.into_result(opts.n_solve_only)
}

/// Operator-product accounting from one [`mbcg_batch_stats`] run — the
/// observable behind the batched-training claim: a sequential sweep pays
/// `system_iterations` covariance passes; the batched loop actually pays
/// `batched_products`. On the shared-covariance fast path every iteration
/// is ONE fused `K·[D₁ … D_k]` pass (so `batched_products` ≈
/// `system_iterations / b`); on the general path each active system
/// contributes its own product and the two counts are equal — the win
/// there is the single iteration loop + per-system early stopping, not
/// fused matmuls.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MbcgBatchStats {
    /// operator products the batched loop actually performed (1 per
    /// iteration on the shared fast path; one per active system otherwise)
    pub batched_products: usize,
    /// sum of per-system iteration counts — the number of operator
    /// products a sequential per-system loop would have paid
    pub system_iterations: usize,
    /// heap allocations observed on the solver thread inside the
    /// iteration loop (debug builds only — release builds always report
    /// 0). With operators implementing `matmul_into`, identity/warm
    /// preconditioners, and a warm [`MbcgWorkspace`], this is 0: the loop
    /// runs entirely in the per-solve arena.
    pub loop_allocs: u64,
}

/// Per-solve scratch arena for the batched iteration loop: the packing
/// block and fused-product buffer for the shared-covariance path, the
/// per-system product and preconditioned-residual buffers, and the
/// active-set index scratch. Everything is sized during setup and reused
/// across iterations (and, for callers holding the workspace, across
/// solves), so the loop itself performs **no heap allocation** — counted
/// in debug builds via [`MbcgBatchStats::loop_allocs`].
#[derive(Default)]
pub struct MbcgWorkspace {
    /// fused-path packing buffer (moved in and out of a shaped `Mat`)
    block: Vec<f64>,
    /// fused-path product output buffer
    kv: Vec<f64>,
    /// per-system operator-product outputs `Aᵢ·Dᵢ`
    vs: Vec<Mat>,
    /// per-system preconditioned residuals `P̂ᵢ⁻¹·Rᵢ`
    zs: Vec<Mat>,
    /// still-active system indices (cleared and refilled per iteration)
    active: Vec<usize>,
}

impl MbcgWorkspace {
    /// An empty workspace; buffers are sized on first use.
    pub fn new() -> Self {
        MbcgWorkspace::default()
    }
}

/// **Batched mBCG across operators**: run `b` independent systems
/// `Aᵢ·Xᵢ = Bᵢ` — one per [`crate::linalg::op::BatchOp`] element — through
/// **one** iteration loop. Every iteration performs a single batched
/// operator product over the still-active systems (on the shared-
/// covariance fast path that is one fused `K·[D₁ … D_k]`), then each
/// system runs its own α/β and tridiagonal bookkeeping.
///
/// **Per-system early stopping**: a system whose columns have all
/// converged freezes — it drops out of the batched product instead of
/// iterating to the global cap, so its `iterations` count (and α/β
/// streams) match a standalone [`mbcg`] run exactly.
///
/// `opts.n_solve_only` is clamped per system to its column count, so
/// `usize::MAX` means "solves only, no tridiagonals anywhere".
pub fn mbcg_batch(
    batch: &crate::linalg::op::BatchOp<'_>,
    bs: &[&Mat],
    preconds: &[&dyn crate::linalg::preconditioner::Preconditioner],
    opts: &MbcgOptions,
) -> Vec<MbcgResult> {
    mbcg_batch_stats(batch, bs, preconds, opts).0
}

/// [`mbcg_batch`] that also reports [`MbcgBatchStats`] — every per-system
/// result carries its own probe solves, tridiagonal matrices, iteration
/// count, and residuals, so a batched inference engine
/// ([`crate::gp::mll::BatchBbmmEngine`]) can run the full §4 derivation
/// (solve + SLQ log-det + paired-trace) per batch element from this one
/// call.
pub fn mbcg_batch_stats(
    batch: &crate::linalg::op::BatchOp<'_>,
    bs: &[&Mat],
    preconds: &[&dyn crate::linalg::preconditioner::Preconditioner],
    opts: &MbcgOptions,
) -> (Vec<MbcgResult>, MbcgBatchStats) {
    let mut ws = MbcgWorkspace::new();
    mbcg_batch_stats_ws(batch, bs, preconds, opts, &mut ws)
}

/// [`mbcg_batch_stats`] against a caller-held [`MbcgWorkspace`]: setup
/// (system state, buffer sizing, plan materialisation via
/// [`crate::linalg::op::BatchOp::prepare`]) happens before the loop, and
/// the loop itself is allocation-free — products are written into the
/// arena through `matmul_into`/`solve_mat_into`, the fused shared-
/// covariance block round-trips through the arena's packing buffers, and
/// the active set reuses one index vector. Callers solving repeatedly
/// (training steps, serving ticks) hold the workspace across calls so
/// even setup stays warm.
pub fn mbcg_batch_stats_ws(
    batch: &crate::linalg::op::BatchOp<'_>,
    bs: &[&Mat],
    preconds: &[&dyn crate::linalg::preconditioner::Preconditioner],
    opts: &MbcgOptions,
    ws: &mut MbcgWorkspace,
) -> (Vec<MbcgResult>, MbcgBatchStats) {
    // setup-phase allocation: per-system option fan-out for the shared core
    let per: Vec<MbcgOptions> = (0..batch.len()).map(|_| *opts).collect();
    mbcg_batch_hetero_ws(batch, bs, preconds, &per, ws)
}

/// **Heterogeneous batched mBCG**: the per-system-options core of
/// [`mbcg_batch_stats_ws`]. Systems may have **different dimensions**
/// (a [`crate::linalg::op::BatchOp::hetero`] stack of mixed-n tenants) and
/// each carries its own `MbcgOptions` — per-block tolerance, iteration
/// cap, and `n_solve_only` — so a mixed batch pays ONE iteration loop per
/// tick while every block stops exactly where its own accuracy target
/// says. A block whose preconditioner is an exact direct solve (see
/// [`crate::linalg::op::solve::PlanPrecond`]) converges at the first
/// α-step and drops out of the batched product immediately, which is how
/// exact-planned (Cholesky/Woodbury/circulant) tenants ride the same fused
/// loop as iterative ones.
pub fn mbcg_batch_hetero_ws(
    batch: &crate::linalg::op::BatchOp<'_>,
    bs: &[&Mat],
    preconds: &[&dyn crate::linalg::preconditioner::Preconditioner],
    opts: &[MbcgOptions],
    ws: &mut MbcgWorkspace,
) -> (Vec<MbcgResult>, MbcgBatchStats) {
    let b = batch.len();
    assert_eq!(bs.len(), b, "mbcg_batch: RHS count mismatch");
    assert_eq!(preconds.len(), b, "mbcg_batch: preconditioner count mismatch");
    assert_eq!(opts.len(), b, "mbcg_batch: options count mismatch");
    // ---- setup: allocation is expected here, never inside the loop ----
    batch.prepare();
    let mut systems: Vec<CgSystem<f64>> = bs
        .iter()
        .zip(preconds)
        .enumerate()
        .map(|(i, (&rhs, pre))| {
            assert_eq!(rhs.rows(), batch.element_n(i), "mbcg_batch: RHS row mismatch");
            CgSystem::new(rhs, pre.solve_mat(rhs), opts[i].max_iters)
        })
        .collect();
    // the shared fast path packs through `block`/`kv` (uniform n by
    // construction); the elementwise path never touches them
    let pack_len = if batch.is_shared() {
        batch.n() * bs.iter().map(|m| m.cols()).sum::<usize>()
    } else {
        0
    };
    if ws.block.len() != pack_len {
        ws.block.clear();
        ws.block.resize(pack_len, 0.0);
    }
    if ws.kv.len() != pack_len {
        ws.kv.clear();
        ws.kv.resize(pack_len, 0.0);
    }
    let shapes_match = ws.vs.len() == b
        && ws
            .vs
            .iter()
            .zip(bs.iter().enumerate())
            .all(|(v, (i, rhs))| v.shape() == (batch.element_n(i), rhs.cols()));
    if !shapes_match {
        ws.vs = bs
            .iter()
            .enumerate()
            .map(|(i, rhs)| Mat::zeros(batch.element_n(i), rhs.cols()))
            .collect();
        ws.zs = bs
            .iter()
            .enumerate()
            .map(|(i, rhs)| Mat::zeros(batch.element_n(i), rhs.cols()))
            .collect();
    }
    ws.active.clear();
    ws.active.reserve(b);
    let mut stats = MbcgBatchStats::default();
    // ---- the iteration loop: the zero-allocation zone ----
    let alloc0 = crate::util::alloc::thread_allocations();
    loop {
        ws.active.clear();
        for (i, sys) in systems.iter().enumerate() {
            if !sys.done() && sys.iterations < opts[i].max_iters {
                ws.active.push(i);
            }
        }
        if ws.active.is_empty() {
            break;
        }
        // ONE fused covariance product for the whole active set on the
        // shared path (pack, multiply, unpack through the workspace
        // arena — the active set only shrinks, so the scratch buffers
        // sized during setup never regrow); elementwise products
        // otherwise. Both paths live in `BatchOp::matmul_subset_into`,
        // the single implementation of the pack/multiply/unpack.
        stats.batched_products += batch.matmul_subset_into(
            &ws.active,
            |i| &systems[i].d,
            &mut ws.vs,
            &mut ws.block,
            &mut ws.kv,
        );
        for k in 0..ws.active.len() {
            let i = ws.active[k];
            let sys = &mut systems[i];
            sys.absorb_product(&ws.vs[i], opts[i].tol);
            if !sys.done() {
                preconds[i].solve_mat_into(&sys.r, &mut ws.zs[i]);
                sys.refresh_directions(&ws.zs[i]);
            }
        }
    }
    stats.loop_allocs = crate::util::alloc::thread_allocations().saturating_sub(alloc0);
    stats.system_iterations = systems.iter().map(|sys| sys.iterations).sum();
    let results = systems
        .into_iter()
        .zip(opts)
        .map(|(sys, o)| sys.into_result(o.n_solve_only))
        .collect();
    (results, stats)
}

/// [`mbcg`] over a composed [`crate::linalg::op::LinearOp`] — the entry
/// point the operator algebra's iterative paths share. The operator is the
/// blackbox `A`; preconditioning stays a caller-supplied closure so engines
/// can reuse a preconditioner across calls.
pub fn mbcg_op(
    op: &dyn crate::linalg::op::LinearOp,
    b: &Mat,
    precond: impl Fn(&Mat) -> Mat,
    opts: &MbcgOptions,
) -> MbcgResult {
    mbcg(|m| op.matmul(m), b, precond, opts)
}

/// A blackbox operator whose `K̂·M` is computed as per-shard row-blocks —
/// the seam between mBCG and the sharded kernel operators (Wang et al.
/// 2019: partition the kernel into row shards so peak memory per worker is
/// O(n·t + shard·n) and shards can map onto devices/processes).
///
/// Shards must be contiguous, disjoint, and cover `0..n` in order.
pub trait ShardedMmm<T: Scalar = f64>: Sync {
    /// number of rows/columns of the implicit SPD matrix
    fn n(&self) -> usize;
    /// number of row shards
    fn n_shards(&self) -> usize;
    /// the contiguous row range owned by shard `s`
    fn shard_rows(&self, s: usize) -> Range<usize>;
    /// Write shard `s`'s row-block of `K̂·M` into `out` (row-major,
    /// `shard_rows(s).len() × m.cols()`, zero-initialised by the caller).
    fn shard_matmul(&self, s: usize, m: &Mat<T>, out: &mut [T]);
}

/// Assemble the full `K̂·M` from per-shard partial products: shards are
/// claimed by a worker pool and each writes its disjoint row-block of the
/// output, so the "reduction" is a concatenation with no extra copies.
pub fn sharded_mmm<T: Scalar>(op: &dyn ShardedMmm<T>, m: &Mat<T>) -> Mat<T> {
    let n = op.n();
    assert_eq!(m.rows(), n);
    let t = m.cols();
    let s = op.n_shards();
    let mut out = Mat::<T>::zeros(n, t);
    {
        // slice the output into per-shard row-blocks (disjoint by contract)
        let mut blocks: Vec<Mutex<&mut [T]>> = Vec::with_capacity(s);
        let mut rest = out.data_mut();
        let mut row = 0;
        for sh in 0..s {
            let r = op.shard_rows(sh);
            assert_eq!(r.start, row, "shards must be contiguous and ordered");
            let (head, tail) = rest.split_at_mut((r.end - r.start) * t);
            blocks.push(Mutex::new(head));
            rest = tail;
            row = r.end;
        }
        assert_eq!(row, n, "shards must cover all rows");
        let workers = par::num_threads().min(s).max(1);
        if workers <= 1 {
            for (sh, block) in blocks.iter().enumerate() {
                let mut guard = block.lock().unwrap();
                op.shard_matmul(sh, m, &mut **guard);
            }
        } else {
            let next = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                for _ in 0..workers {
                    let next = &next;
                    let blocks = &blocks;
                    scope.spawn(move || loop {
                        let sh = next.fetch_add(1, Ordering::Relaxed);
                        if sh >= s {
                            break;
                        }
                        let mut guard = blocks[sh].lock().unwrap();
                        op.shard_matmul(sh, m, &mut **guard);
                    });
                }
            });
        }
    }
    out
}

/// [`mbcg`] whose per-iteration `mmm_A` is the shard-assembled product of
/// [`sharded_mmm`] — the million-point configuration, where the monolithic
/// operator walk is replaced by per-shard work queues.
pub fn mbcg_sharded<T: Scalar>(
    op: &dyn ShardedMmm<T>,
    b: &Mat<T>,
    precond: impl Fn(&Mat<T>) -> Mat<T>,
    opts: &MbcgOptions,
) -> MbcgResult<T> {
    mbcg(|m| sharded_mmm(op, m), b, precond, opts)
}

/// Observation 3 (Saad §6.7.3): rebuild the Lanczos `T̃` from CG's α/β.
pub fn tridiag_from_coeffs(alphas: &[f64], betas: &[f64]) -> TriDiag {
    let p = alphas.len();
    let mut diag = Vec::with_capacity(p);
    let mut offdiag = Vec::with_capacity(p.saturating_sub(1));
    for j in 0..p {
        let mut t = 1.0 / alphas[j];
        if j > 0 {
            t += betas[j - 1] / alphas[j - 1];
        }
        diag.push(t);
        if j + 1 < p {
            // guard: β can dip fractionally below 0 in finite precision
            offdiag.push(betas[j].max(0.0).sqrt() / alphas[j]);
        }
    }
    TriDiag { diag, offdiag }
}

/// Strided column dot — the α/β reductions of every CG step run through
/// here. f64 columns dispatch through [`crate::tensor::simd`] (contiguous
/// kernel when `t == 1`, the serving predict shape; lane-composed strided
/// kernel otherwise); the portable path keeps four independent
/// accumulators so a single chain never serialises on the add latency.
/// Neither path allocates — this sits inside the mBCG zero-alloc loop.
#[inline]
fn col_dot<T: Scalar>(a: &Mat<T>, b: &Mat<T>, c: usize) -> f64 {
    let n = a.rows();
    if std::any::TypeId::of::<T>() == std::any::TypeId::of::<f64>() && a.cols() == b.cols() {
        // SAFETY: T == f64, just checked — identity casts
        let (af, bf) = unsafe {
            (
                crate::tensor::gemm::cast_slice::<T, f64>(a.data()),
                crate::tensor::gemm::cast_slice::<T, f64>(b.data()),
            )
        };
        let t = a.cols();
        let hit = if t == 1 {
            crate::tensor::simd::dot_f64(af, bf)
        } else {
            crate::tensor::simd::dot_strided_f64(af, bf, c, t, n)
        };
        if let Some(s) = hit {
            return s;
        }
    }
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let end = n - n % 4;
    let mut i = 0;
    while i < end {
        s0 += a.get(i, c).to_f64() * b.get(i, c).to_f64();
        s1 += a.get(i + 1, c).to_f64() * b.get(i + 1, c).to_f64();
        s2 += a.get(i + 2, c).to_f64() * b.get(i + 2, c).to_f64();
        s3 += a.get(i + 3, c).to_f64() * b.get(i + 3, c).to_f64();
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while i < n {
        s += a.get(i, c).to_f64() * b.get(i, c).to_f64();
        i += 1;
    }
    s
}

#[inline]
fn col_norm<T: Scalar>(a: &Mat<T>, c: usize) -> f64 {
    col_dot(a, a, c).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky::Cholesky;
    use crate::linalg::lanczos::lanczos_tridiag;
    use crate::util::Rng;

    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = g.t_matmul(&g);
        a.add_diag(n as f64 * 0.5);
        a
    }

    #[test]
    fn batched_solves_match_cholesky() {
        let n = 70;
        let s = 6;
        let a = spd(n, 1);
        let mut rng = Rng::new(2);
        let b = Mat::from_fn(n, s, |_, _| rng.normal());
        let res = mbcg(
            |m| a.matmul(m),
            &b,
            |m| m.clone(),
            &MbcgOptions {
                max_iters: n,
                tol: 1e-12,
                n_solve_only: 0,
            },
        );
        let want = Cholesky::new(&a).unwrap().solve_mat(&b);
        assert!(res.solves.max_abs_diff(&want) < 1e-8);
    }

    #[test]
    fn batched_matches_sequential_cg() {
        // mBCG column c must equal a standalone CG on (A, b_c) at equal iters
        let n = 50;
        let a = spd(n, 3);
        let mut rng = Rng::new(4);
        let b = Mat::from_fn(n, 3, |_, _| rng.normal());
        let p = 10;
        let res = mbcg(
            |m| a.matmul(m),
            &b,
            |m| m.clone(),
            &MbcgOptions {
                max_iters: p,
                tol: 0.0,
                n_solve_only: 0,
            },
        );
        for c in 0..3 {
            let single = crate::linalg::cg::pcg_dense(&a, &b.col(c), p, 0.0);
            for i in 0..n {
                assert!(
                    (res.solves.get(i, c) - single.x[i]).abs() < 1e-9,
                    "col {c} row {i}"
                );
            }
        }
    }

    #[test]
    fn tridiag_matches_explicit_lanczos() {
        // The recovered T̃ must match the Lanczos tridiagonalization with the
        // (normalized) RHS as the probe vector.
        let n = 40;
        let a = spd(n, 5);
        let mut rng = Rng::new(6);
        let z = rng.normal_vec(n);
        let b = Mat::from_vec(n, 1, z.clone());
        let p = 12;
        let res = mbcg(
            |m| a.matmul(m),
            &b,
            |m| m.clone(),
            &MbcgOptions {
                max_iters: p,
                tol: 0.0,
                n_solve_only: 0,
            },
        );
        let t_cg = &res.tridiags[0];
        let (t_lz, _q) = lanczos_tridiag(|v| a.matvec(v), &z, p);
        assert_eq!(t_cg.n(), t_lz.n());
        for i in 0..t_cg.n() {
            assert!(
                (t_cg.diag[i] - t_lz.diag[i]).abs() < 1e-6 * t_lz.diag[i].abs().max(1.0),
                "diag {i}: {} vs {}",
                t_cg.diag[i],
                t_lz.diag[i]
            );
        }
        for i in 0..t_cg.n() - 1 {
            assert!(
                (t_cg.offdiag[i].abs() - t_lz.offdiag[i].abs()).abs() < 1e-6,
                "offdiag {i}"
            );
        }
    }

    #[test]
    fn tridiag_eigenvalues_within_spectrum() {
        // Ritz values (eigenvalues of T̃) must lie inside [λmin, λmax] of A
        let n = 30;
        let a = spd(n, 7);
        let mut rng = Rng::new(8);
        let b = Mat::from_fn(n, 2, |_, _| rng.rademacher());
        let res = mbcg(
            |m| a.matmul(m),
            &b,
            |m| m.clone(),
            &MbcgOptions {
                max_iters: 10,
                tol: 0.0,
                n_solve_only: 0,
            },
        );
        // Gershgorin bound for λmax of A; λmin > 0 since SPD
        let mut lmax = 0.0f64;
        for i in 0..n {
            let row_sum: f64 = (0..n).map(|j| a.get(i, j).abs()).sum();
            lmax = lmax.max(row_sum);
        }
        for t in &res.tridiags {
            let eig = crate::linalg::tridiag::SymTridiagEig::new(&t.diag, &t.offdiag);
            for &l in &eig.eigenvalues {
                assert!(l > 0.0 && l <= lmax * (1.0 + 1e-8), "ritz {l} not in (0,{lmax}]");
            }
        }
    }

    #[test]
    fn solve_only_columns_skip_tridiags() {
        let n = 20;
        let a = spd(n, 9);
        let mut rng = Rng::new(10);
        let b = Mat::from_fn(n, 4, |_, _| rng.normal());
        let res = mbcg(
            |m| a.matmul(m),
            &b,
            |m| m.clone(),
            &MbcgOptions {
                max_iters: 10,
                tol: 0.0,
                n_solve_only: 1,
            },
        );
        assert_eq!(res.tridiags.len(), 3);
    }

    #[test]
    fn early_stopping_freezes_converged_columns() {
        // one easy column (small norm already solved) + one hard column
        let n = 40;
        let a = spd(n, 11);
        let mut rng = Rng::new(12);
        let b = Mat::from_fn(n, 2, |_, _| rng.normal());
        let res = mbcg(
            |m| a.matmul(m),
            &b,
            |m| m.clone(),
            &MbcgOptions {
                max_iters: n * 2,
                tol: 1e-11,
                n_solve_only: 0,
            },
        );
        for c in 0..2 {
            assert!(res.final_residuals[c] < 1e-10, "col {c}");
        }
        assert!(res.iterations <= n + 5);
    }

    #[test]
    fn preconditioned_mbcg_converges_faster() {
        // use the exact inverse as (an extreme) preconditioner: 1 iteration
        let n = 35;
        let a = spd(n, 13);
        let ch = Cholesky::new(&a).unwrap();
        let mut rng = Rng::new(14);
        let b = Mat::from_fn(n, 2, |_, _| rng.normal());
        let res = mbcg(
            |m| a.matmul(m),
            &b,
            |m| ch.solve_mat(m),
            &MbcgOptions {
                max_iters: 50,
                tol: 1e-10,
                n_solve_only: 0,
            },
        );
        assert!(res.iterations <= 3, "took {}", res.iterations);
        let plain = mbcg(
            |m| a.matmul(m),
            &b,
            |m| m.clone(),
            &MbcgOptions {
                max_iters: 50,
                tol: 1e-10,
                n_solve_only: 0,
            },
        );
        assert!(plain.iterations > res.iterations);
    }

    #[test]
    fn zero_rhs_column_handled() {
        let n = 15;
        let a = spd(n, 15);
        let mut b = Mat::zeros(n, 2);
        let mut rng = Rng::new(16);
        b.set_col(1, &rng.normal_vec(n));
        let res = mbcg(|m| a.matmul(m), &b, |m| m.clone(), &MbcgOptions::default());
        for i in 0..n {
            assert_eq!(res.solves.get(i, 0), 0.0);
        }
    }

    /// Toy sharded operator over an explicit dense SPD matrix: shard `s`
    /// multiplies its row-block of `A` against `M`.
    struct DenseSharded {
        a: Mat,
        shards: Vec<std::ops::Range<usize>>,
    }

    impl DenseSharded {
        fn new(a: Mat, n_shards: usize) -> Self {
            let shards = crate::runtime::shard::partition_rows(a.rows(), n_shards);
            DenseSharded { a, shards }
        }
    }

    impl ShardedMmm for DenseSharded {
        fn n(&self) -> usize {
            self.a.rows()
        }
        fn n_shards(&self) -> usize {
            self.shards.len()
        }
        fn shard_rows(&self, s: usize) -> std::ops::Range<usize> {
            self.shards[s].clone()
        }
        fn shard_matmul(&self, s: usize, m: &Mat, out: &mut [f64]) {
            let t = m.cols();
            let rows = self.shards[s].clone();
            for (ri, i) in rows.enumerate() {
                let arow = self.a.row(i);
                let orow = &mut out[ri * t..(ri + 1) * t];
                for (j, &av) in arow.iter().enumerate() {
                    let mrow = m.row(j);
                    for c in 0..t {
                        orow[c] += av * mrow[c];
                    }
                }
            }
        }
    }

    #[test]
    fn sharded_mmm_assembles_the_full_product() {
        let n = 83;
        let a = spd(n, 21);
        let mut rng = Rng::new(22);
        let m = Mat::from_fn(n, 5, |_, _| rng.normal());
        let want = a.matmul(&m);
        for &s in &[1usize, 2, 5, 16, n] {
            let op = DenseSharded::new(a.clone(), s);
            let got = sharded_mmm(&op, &m);
            assert!(
                got.max_abs_diff(&want) < 1e-11,
                "shards {s}: {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn mbcg_sharded_matches_monolithic_mbcg() {
        let n = 64;
        let a = spd(n, 23);
        let mut rng = Rng::new(24);
        let b = Mat::from_fn(n, 4, |_, _| rng.normal());
        let opts = MbcgOptions {
            max_iters: n,
            tol: 1e-12,
            n_solve_only: 1,
        };
        let mono = mbcg(|m| a.matmul(m), &b, |m| m.clone(), &opts);
        let op = DenseSharded::new(a.clone(), 7);
        let shrd = mbcg_sharded(&op, &b, |m| m.clone(), &opts);
        assert!(shrd.solves.max_abs_diff(&mono.solves) < 1e-9);
        assert_eq!(shrd.iterations, mono.iterations);
        assert_eq!(shrd.tridiags.len(), mono.tridiags.len());
        let want = Cholesky::new(&a).unwrap().solve_mat(&b);
        assert!(shrd.solves.max_abs_diff(&want) < 1e-7);
    }

    #[test]
    fn mbcg_batch_matches_standalone_mbcg_per_system() {
        use crate::linalg::op::{BatchOp, DenseOp, LinearOp};
        use crate::linalg::preconditioner::{IdentityPrecond, Preconditioner};
        let n = 45;
        let ops: Vec<DenseOp> = (0..4).map(|k| DenseOp::new(spd(n, 30 + k))).collect();
        let els: Vec<&dyn LinearOp> = ops.iter().map(|o| o as &dyn LinearOp).collect();
        let batch = BatchOp::new(els);
        let mut rng = Rng::new(40);
        let bs: Vec<Mat> = (0..4)
            .map(|k| Mat::from_fn(n, 1 + k % 3, |_, _| rng.normal()))
            .collect();
        let b_refs: Vec<&Mat> = bs.iter().collect();
        let id = IdentityPrecond;
        let preconds: Vec<&dyn Preconditioner> = (0..4).map(|_| &id as &dyn Preconditioner).collect();
        let opts = MbcgOptions {
            max_iters: n,
            tol: 1e-11,
            n_solve_only: 0,
        };
        let batched = mbcg_batch(&batch, &b_refs, &preconds, &opts);
        for (k, res) in batched.iter().enumerate() {
            let mono = mbcg(|m| ops[k].matmul(m), &bs[k], |m| m.clone(), &opts);
            // same operator product order per column ⇒ bitwise-equal runs
            assert_eq!(res.iterations, mono.iterations, "system {k}");
            assert!(res.solves.max_abs_diff(&mono.solves) < 1e-12, "system {k}");
            assert_eq!(res.tridiags.len(), mono.tridiags.len());
            for (a, b) in res.tridiags.iter().zip(mono.tridiags.iter()) {
                assert_eq!(a.n(), b.n());
            }
        }
    }

    #[test]
    fn mbcg_batch_per_system_early_stopping_freezes_easy_systems() {
        use crate::linalg::op::{BatchOp, DenseOp, LinearOp};
        use crate::linalg::preconditioner::{IdentityPrecond, Preconditioner};
        let n = 60;
        // well-conditioned system (heavy diagonal) vs ill-conditioned one
        let mut easy = spd(n, 50);
        easy.add_diag(n as f64 * 50.0);
        let mut rng = Rng::new(51);
        let xs: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let mut hard = Mat::from_fn(n, n, |i, j| {
            let d = xs[i] - xs[j];
            (-d * d / 0.5).exp()
        });
        hard.add_diag(1e-4);
        let easy_op = DenseOp::new(easy);
        let hard_op = DenseOp::new(hard);
        let batch = BatchOp::new(vec![&easy_op as &dyn LinearOp, &hard_op as &dyn LinearOp]);
        let b1 = Mat::from_fn(n, 2, |_, _| rng.normal());
        let b2 = Mat::from_fn(n, 2, |_, _| rng.normal());
        let id = IdentityPrecond;
        let preconds: Vec<&dyn Preconditioner> = vec![&id, &id];
        let opts = MbcgOptions {
            max_iters: 2 * n,
            tol: 1e-10,
            n_solve_only: usize::MAX,
        };
        let res = mbcg_batch(&batch, &[&b1, &b2], &preconds, &opts);
        assert!(
            res[0].iterations < res[1].iterations,
            "easy system must freeze early: {} vs {}",
            res[0].iterations,
            res[1].iterations
        );
        assert!(res[0].final_residuals.iter().all(|&r| r < 1e-10));
        assert!(res[0].tridiags.is_empty(), "n_solve_only=MAX skips tridiags");
    }

    #[test]
    fn mbcg_batch_shared_fast_path_matches_general() {
        use crate::linalg::op::{AddedDiagOp, BatchOp, DenseOp, LinearOp};
        use crate::linalg::preconditioner::{IdentityPrecond, Preconditioner};
        let n = 35;
        let k = spd(n, 60);
        let cov = DenseOp::new(k);
        let sigma2s = vec![0.3, 0.9, 2.5, 0.05];
        let shared = BatchOp::shared(&cov, sigma2s.clone());
        let composed: Vec<AddedDiagOp<&DenseOp>> = sigma2s
            .iter()
            .map(|&s| AddedDiagOp::new(&cov, s))
            .collect();
        let els: Vec<&dyn LinearOp> = composed.iter().map(|o| o as &dyn LinearOp).collect();
        let general = BatchOp::new(els);
        assert!(!general.is_shared(), "distinct wrappers defeat ptr detection");
        let mut rng = Rng::new(61);
        let bs: Vec<Mat> = (0..4).map(|_| Mat::from_fn(n, 2, |_, _| rng.normal())).collect();
        let b_refs: Vec<&Mat> = bs.iter().collect();
        let id = IdentityPrecond;
        let preconds: Vec<&dyn Preconditioner> = (0..4).map(|_| &id as &dyn Preconditioner).collect();
        let opts = MbcgOptions {
            max_iters: n,
            tol: 1e-11,
            n_solve_only: usize::MAX,
        };
        let fast = mbcg_batch(&shared, &b_refs, &preconds, &opts);
        let slow = mbcg_batch(&general, &b_refs, &preconds, &opts);
        for i in 0..4 {
            assert_eq!(fast[i].iterations, slow[i].iterations, "system {i}");
            assert!(fast[i].solves.max_abs_diff(&slow[i].solves) < 1e-12, "system {i}");
        }
    }

    #[test]
    fn f32_solves_reach_f32_accuracy() {
        let n = 40;
        let a64 = spd(n, 17);
        let a: Mat<f32> = a64.cast();
        let mut rng = Rng::new(18);
        let b64 = Mat::from_fn(n, 2, |_, _| rng.normal());
        let b: Mat<f32> = b64.cast();
        let res = mbcg(
            |m| a.matmul(m),
            &b,
            |m| m.clone(),
            &MbcgOptions {
                max_iters: 100,
                tol: 1e-6,
                n_solve_only: 0,
            },
        );
        let want = Cholesky::new(&a64).unwrap().solve_mat(&b64);
        assert!(res.solves.cast::<f64>().max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn mbcg_batch_hetero_matches_standalone_per_system() {
        use crate::linalg::op::{BatchOp, DenseOp, LinearOp};
        use crate::linalg::preconditioner::{IdentityPrecond, Preconditioner};
        // mixed sizes — the heterogeneous serving shape
        let ns = [23usize, 57, 40];
        let ops: Vec<DenseOp> = ns
            .iter()
            .enumerate()
            .map(|(k, &n)| DenseOp::new(spd(n, 70 + k as u64)))
            .collect();
        let els: Vec<&dyn LinearOp> = ops.iter().map(|o| o as &dyn LinearOp).collect();
        let batch = BatchOp::hetero(els);
        let mut rng = Rng::new(71);
        let bs: Vec<Mat> = ns
            .iter()
            .enumerate()
            .map(|(k, &n)| Mat::from_fn(n, 1 + k % 2, |_, _| rng.normal()))
            .collect();
        let b_refs: Vec<&Mat> = bs.iter().collect();
        let id = IdentityPrecond;
        let preconds: Vec<&dyn Preconditioner> =
            (0..3).map(|_| &id as &dyn Preconditioner).collect();
        let opts: Vec<MbcgOptions> = ns
            .iter()
            .map(|&n| MbcgOptions {
                max_iters: n,
                tol: 1e-11,
                n_solve_only: 0,
            })
            .collect();
        let mut ws = MbcgWorkspace::new();
        let (batched, stats) = mbcg_batch_hetero_ws(&batch, &b_refs, &preconds, &opts, &mut ws);
        assert!(stats.batched_products > 0);
        for (k, res) in batched.iter().enumerate() {
            let mono = mbcg(|m| ops[k].matmul(m), &bs[k], |m| m.clone(), &opts[k]);
            // same operator product order per column ⇒ bitwise-equal runs
            assert_eq!(res.iterations, mono.iterations, "system {k}");
            assert!(res.solves.max_abs_diff(&mono.solves) < 1e-12, "system {k}");
        }
        // workspace reuse across a second call must not disturb results
        let (again, _) = mbcg_batch_hetero_ws(&batch, &b_refs, &preconds, &opts, &mut ws);
        for (a, b) in batched.iter().zip(&again) {
            assert!(a.solves.max_abs_diff(&b.solves) == 0.0);
        }
    }

    #[test]
    fn mbcg_batch_hetero_per_block_tolerance_stops_blocks_independently() {
        use crate::linalg::op::{BatchOp, DenseOp, LinearOp};
        use crate::linalg::preconditioner::{IdentityPrecond, Preconditioner};
        let (na, nb) = (48usize, 32usize);
        let oa = DenseOp::new(spd(na, 80));
        let ob = DenseOp::new(spd(nb, 81));
        let batch = BatchOp::hetero(vec![&oa as &dyn LinearOp, &ob as &dyn LinearOp]);
        let mut rng = Rng::new(82);
        let ba = Mat::from_fn(na, 2, |_, _| rng.normal());
        let bb = Mat::from_fn(nb, 2, |_, _| rng.normal());
        let id = IdentityPrecond;
        let preconds: Vec<&dyn Preconditioner> = vec![&id, &id];
        // block 0 wants full accuracy, block 1 accepts a loose answer
        let opts = [
            MbcgOptions {
                max_iters: na,
                tol: 1e-11,
                n_solve_only: usize::MAX,
            },
            MbcgOptions {
                max_iters: nb,
                tol: 1e-2,
                n_solve_only: usize::MAX,
            },
        ];
        let mut ws = MbcgWorkspace::new();
        let (res, _) = mbcg_batch_hetero_ws(&batch, &[&ba, &bb], &preconds, &opts, &mut ws);
        assert!(
            res[1].iterations < res[0].iterations,
            "loose-tol block must drop out of the fused loop early: {} vs {}",
            res[1].iterations,
            res[0].iterations
        );
        assert!(res[0].final_residuals.iter().all(|&r| r < 1e-11));
        assert!(res[1].final_residuals.iter().all(|&r| r < 1e-2));
    }
}
