//! Stochastic trace estimation (Hutchinson [25]; paper eq. 4).
//!
//! `Tr(A) = E[zᵀ A z]` for probes with `E[z zᵀ] = I`; with solves from mBCG
//! this turns the gradient trace term `Tr(K̂⁻¹ dK̂/dθ)` into elementwise
//! products of matrices mBCG already produced.

use crate::tensor::Mat;
use crate::util::Rng;

/// Generic Hutchinson estimator: `mean_i zᵢᵀ (A zᵢ)` with Rademacher probes.
pub fn hutchinson_trace(
    matvec: impl Fn(&[f64]) -> Vec<f64>,
    n: usize,
    t: usize,
    rng: &mut Rng,
) -> f64 {
    let mut acc = 0.0;
    let mut z = vec![0.0; n];
    for _ in 0..t {
        rng.fill_rademacher(&mut z);
        let az = matvec(&z);
        acc += z.iter().zip(az.iter()).map(|(a, b)| a * b).sum::<f64>();
    }
    acc / t as f64
}

/// Paired-solve trace estimator (paper eq. 4):
/// `Tr(K̂⁻¹ dK̂) ≈ mean_i (K̂⁻¹zᵢ)ᵀ (dK̂ wᵢ)` where
/// * `solves` holds `K̂⁻¹zᵢ` in columns,
/// * `dk_probes` holds `dK̂·wᵢ` in columns,
/// * with `wᵢ = zᵢ` when unpreconditioned, or `wᵢ = P̂⁻¹zᵢ`, `zᵢ ~ N(0,P̂)`
///   when preconditioned (then `E[zᵢ wᵢᵀ] = I` still holds in the right
///   sense: `E[K̂⁻¹z zᵀP̂⁻¹ dK̂] = K̂⁻¹dK̂`).
pub fn paired_trace(solves: &Mat, dk_probes: &Mat) -> f64 {
    assert_eq!(solves.shape(), dk_probes.shape());
    let t = solves.cols();
    assert!(t > 0);
    let mut acc = 0.0;
    for c in 0..t {
        for r in 0..solves.rows() {
            acc += solves.get(r, c) * dk_probes.get(r, c);
        }
    }
    acc / t as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn hutchinson_unbiased_on_dense_matrix() {
        let n = 30;
        let mut rng = Rng::new(1);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = g.t_matmul(&g);
        a.add_diag(2.0);
        let true_tr: f64 = (0..n).map(|i| a.get(i, i)).sum();
        let est = hutchinson_trace(|v| a.matvec(v), n, 4000, &mut rng);
        assert!(
            (est - true_tr).abs() / true_tr < 0.05,
            "est {est} vs {true_tr}"
        );
    }

    #[test]
    fn hutchinson_exact_for_diagonal() {
        // zᵢ ∈ {±1} ⇒ zᵀ D z = Tr(D) exactly, every sample
        let n = 10;
        let d = Mat::from_fn(n, n, |i, j| if i == j { (i + 1) as f64 } else { 0.0 });
        let mut rng = Rng::new(2);
        let est = hutchinson_trace(|v| d.matvec(v), n, 1, &mut rng);
        assert!((est - 55.0).abs() < 1e-12);
    }

    #[test]
    fn paired_trace_matches_direct_product_trace() {
        // Tr(A⁻¹ B) estimated with many probes ≈ exact
        let n = 20;
        let mut rng = Rng::new(3);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = g.t_matmul(&g);
        a.add_diag(n as f64);
        let h = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut b = h.t_matmul(&h);
        b.symmetrize();
        let ch = crate::linalg::cholesky::Cholesky::new(&a).unwrap();

        let t = 6000;
        let z = Mat::from_fn(n, t, |_, _| rng.rademacher());
        let solves = ch.solve_mat(&z); // A⁻¹ Z
        let bz = b.matmul(&z); // B Z
        let est = paired_trace(&solves, &bz);

        // exact: Tr(A⁻¹B) = Σᵢ (A⁻¹ B)ᵢᵢ
        let ainv_b = ch.solve_mat(&b);
        let exact: f64 = (0..n).map(|i| ainv_b.get(i, i)).sum();
        assert!((est - exact).abs() / exact.abs().max(1.0) < 0.05);
    }

    #[test]
    fn variance_shrinks_with_probe_count() {
        let n = 40;
        let mut rng = Rng::new(4);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        let a = g.t_matmul(&g);
        let true_tr: f64 = (0..n).map(|i| a.get(i, i)).sum();
        let err = |t: usize, seed: u64| {
            let mut errs = 0.0;
            for rep in 0..20 {
                let mut r = Rng::new(seed + rep);
                let e = hutchinson_trace(|v| a.matvec(v), n, t, &mut r);
                errs += (e - true_tr).powi(2);
            }
            (errs / 20.0).sqrt()
        };
        let rmse_small = err(4, 100);
        let rmse_big = err(64, 200);
        assert!(rmse_big < rmse_small, "{rmse_big} !< {rmse_small}");
    }
}
