//! Kronecker-product operator substrate.
//!
//! Multi-task GPs (paper §5, Bonilla et al. [5]) use `K = B ⊗ K_data`
//! where `B (q×q)` is the task covariance; KISS-GP in higher dimensions
//! uses Kronecker-structured `K_UU`. The key identity is
//!
//! ```text
//! (A ⊗ B) vec(X) = vec(B X Aᵀ)
//! ```
//!
//! so a mat-vec with an (qa·qb)-dimensional Kronecker matrix costs two
//! small GEMMs instead of one huge one.

use crate::tensor::Mat;

/// `(A ⊗ B) · v` where `A` is qa×qa, `B` is qb×qb, `v` has length qa·qb.
///
/// Layout convention: `v[i*qb + j]` pairs A-index `i` with B-index `j`
/// (row-major vec of the qa×qb matrix X with `X[i,j] = v[i*qb+j]`).
pub fn kron_matvec(a: &Mat, b: &Mat, v: &[f64]) -> Vec<f64> {
    let qa = a.rows();
    let qb = b.rows();
    assert_eq!(a.cols(), qa, "A must be square");
    assert_eq!(b.cols(), qb, "B must be square");
    assert_eq!(v.len(), qa * qb);
    // X = reshape(v, qa×qb); result = vec(A X Bᵀ)
    let x = Mat::from_vec(qa, qb, v.to_vec());
    let ax = a.matmul(&x); // qa×qb
    let out = ax.matmul_t(b); // (A X) Bᵀ
    out.data().to_vec()
}

/// `(A ⊗ B) · M` for a matrix of RHS columns.
pub fn kron_matmul(a: &Mat, b: &Mat, m: &Mat) -> Mat {
    let n = a.rows() * b.rows();
    assert_eq!(m.rows(), n);
    let mut out = Mat::zeros(n, m.cols());
    for c in 0..m.cols() {
        let col = kron_matvec(a, b, &m.col(c));
        out.set_col(c, &col);
    }
    out
}

/// Dense Kronecker product (tests / small sizes).
pub fn kron_dense(a: &Mat, b: &Mat) -> Mat {
    let (ra, ca) = a.shape();
    let (rb, cb) = b.shape();
    Mat::from_fn(ra * rb, ca * cb, |i, j| {
        a.get(i / rb, j / cb) * b.get(i % rb, j % cb)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = g.t_matmul(&g);
        a.add_diag(n as f64 * 0.1);
        a
    }

    #[test]
    fn kron_matvec_matches_dense() {
        let a = rand_spd(3, 1);
        let b = rand_spd(4, 2);
        let mut rng = Rng::new(3);
        let v = rng.normal_vec(12);
        let got = kron_matvec(&a, &b, &v);
        let want = kron_dense(&a, &b).matvec(&v);
        for i in 0..12 {
            assert!((got[i] - want[i]).abs() < 1e-10, "i={i}");
        }
    }

    #[test]
    fn kron_matmul_matches_dense() {
        let a = rand_spd(2, 4);
        let b = rand_spd(5, 5);
        let mut rng = Rng::new(6);
        let m = Mat::from_fn(10, 3, |_, _| rng.normal());
        let got = kron_matmul(&a, &b, &m);
        let want = kron_dense(&a, &b).matmul(&m);
        assert!(got.max_abs_diff(&want) < 1e-10);
    }

    #[test]
    fn kron_dense_shapes_and_values() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let k = kron_dense(&a, &b);
        assert_eq!(k.shape(), (4, 4));
        assert_eq!(k.get(0, 1), 1.0); // a00*b01
        assert_eq!(k.get(2, 3), 4.0); // a11*b01
    }

    #[test]
    fn kron_identity_is_identity() {
        let i2 = Mat::eye(2);
        let i3 = Mat::eye(3);
        let k = kron_dense(&i2, &i3);
        assert!(k.max_abs_diff(&Mat::eye(6)) == 0.0);
    }
}
