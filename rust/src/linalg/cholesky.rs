//! Dense Cholesky factorization — the O(n³) baseline inference engine the
//! paper compares against (§6 uses GPFlow's Cholesky engine; this is the
//! same algorithm on this testbed).
//!
//! Blocked right-looking factorization: the trailing-submatrix update is the
//! dominant cost and is expressed as a parallel GEMM, which is as friendly
//! to this hardware as a Cholesky gets — making it a fair baseline.

use crate::tensor::{Mat, Scalar};
use crate::util::par;

/// Lower-triangular Cholesky factor `A = L·Lᵀ` with solve / logdet helpers.
pub struct Cholesky<T: Scalar = f64> {
    l: Mat<T>,
    /// jitter that had to be added to the diagonal for success (0 if none)
    pub jitter: f64,
}

/// Error raised when a matrix is not positive definite even after the
/// maximum jitter is applied.
#[derive(Debug, Clone, PartialEq)]
pub struct NotPositiveDefinite {
    pub pivot: usize,
    pub value: f64,
}

impl std::fmt::Display for NotPositiveDefinite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "matrix not positive definite at pivot {} (value {:.3e})",
            self.pivot, self.value
        )
    }
}

impl std::error::Error for NotPositiveDefinite {}

impl<T: Scalar> Cholesky<T> {
    /// Factor `a` (symmetric positive definite). Fails rather than jittering.
    pub fn new(a: &Mat<T>) -> Result<Self, NotPositiveDefinite> {
        Self::factor(a, T::ZERO).map(|l| Cholesky { l, jitter: 0.0 })
    }

    /// Factor with escalating jitter — mirrors what Cholesky-based GP
    /// libraries do in practice (the paper calls this out in §6: "Cholesky
    /// methods frequently add noise to the diagonal").
    pub fn new_with_jitter(a: &Mat<T>) -> Result<Self, NotPositiveDefinite> {
        let mut jitter = 0.0f64;
        let mut last_err = NotPositiveDefinite {
            pivot: 0,
            value: 0.0,
        };
        // escalation schedule: 0, 1e-8, 1e-6, 1e-4 (relative to mean diag)
        let mean_diag = (0..a.rows())
            .map(|i| a.get(i, i).to_f64())
            .sum::<f64>()
            / a.rows().max(1) as f64;
        for &rel in &[0.0, 1e-8, 1e-6, 1e-4] {
            jitter = rel * mean_diag.max(1.0);
            match Self::factor(a, T::from_f64(jitter)) {
                Ok(l) => return Ok(Cholesky { l, jitter }),
                Err(e) => last_err = e,
            }
        }
        let _ = jitter;
        Err(last_err)
    }

    /// Blocked right-looking factorization of `a + jitter·I`.
    fn factor(a: &Mat<T>, jitter: T) -> Result<Mat<T>, NotPositiveDefinite> {
        assert_eq!(a.rows(), a.cols(), "cholesky needs a square matrix");
        let n = a.rows();
        let mut l = a.clone();
        if jitter != T::ZERO {
            l.add_diag(jitter);
        }
        const NB: usize = 64;
        let mut kb = 0usize;
        while kb < n {
            let kend = (kb + NB).min(n);
            // factor the diagonal block (unblocked)
            for k in kb..kend {
                let mut d = l.get(k, k);
                for j in kb..k {
                    let v = l.get(k, j);
                    d -= v * v;
                }
                if d <= T::ZERO || !d.is_finite() {
                    return Err(NotPositiveDefinite {
                        pivot: k,
                        value: d.to_f64(),
                    });
                }
                let dk = d.sqrt();
                l.set(k, k, dk);
                // update column below within the panel
                for i in (k + 1)..n {
                    let mut s = l.get(i, k);
                    for j in kb..k {
                        s -= l.get(i, j) * l.get(k, j);
                    }
                    l.set(i, k, s / dk);
                }
            }
            // trailing update: A[kend.., kend..] -= L_panel · L_panelᵀ
            // (parallel over trailing rows — this is the GEMM-shaped bulk)
            if kend < n {
                let panel = Mat::from_fn(n - kend, kend - kb, |r, c| l.get(kend + r, kb + c));
                let nrows = n - kend;
                let ncols_panel = kend - kb;
                // row-parallel rank-NB update of the lower triangle
                let lptr = std::sync::Mutex::new(&mut l);
                par::parallel_chunks(nrows, 8, |_t, lo, hi| {
                    // compute updates into a local buffer, then write under lock
                    let mut updates: Vec<(usize, Vec<T>)> = Vec::with_capacity(hi - lo);
                    for r in lo..hi {
                        let prow = panel.row(r);
                        let mut urow = vec![T::ZERO; r + 1];
                        for (c, u) in urow.iter_mut().enumerate() {
                            let qrow = panel.row(c);
                            let mut s = T::ZERO;
                            for k in 0..ncols_panel {
                                s += prow[k] * qrow[k];
                            }
                            *u = s;
                        }
                        updates.push((r, urow));
                    }
                    let mut guard = lptr.lock().unwrap();
                    for (r, urow) in updates {
                        for (c, u) in urow.iter().enumerate() {
                            let old = guard.get(kend + r, kend + c);
                            guard.set(kend + r, kend + c, old - *u);
                        }
                    }
                });
            }
            kb = kend;
        }
        // zero the strict upper triangle
        for r in 0..n {
            for c in (r + 1)..n {
                l.set(r, c, T::ZERO);
            }
        }
        Ok(l)
    }

    pub fn l(&self) -> &Mat<T> {
        &self.l
    }

    pub fn n(&self) -> usize {
        self.l.rows()
    }

    /// Solve `A x = b` via forward/backward substitution.
    pub fn solve_vec(&self, b: &[T]) -> Vec<T> {
        let n = self.n();
        assert_eq!(b.len(), n);
        let mut x = b.to_vec();
        // forward: L y = b
        for i in 0..n {
            let mut s = x[i];
            let row = self.l.row(i);
            for j in 0..i {
                s -= row[j] * x[j];
            }
            x[i] = s / row[i];
        }
        // backward: Lᵀ x = y
        for i in (0..n).rev() {
            let mut s = x[i];
            for j in (i + 1)..n {
                s -= self.l.get(j, i) * x[j];
            }
            x[i] = s / self.l.get(i, i);
        }
        x
    }

    /// Solve `A X = B` for a matrix of right-hand sides.
    ///
    /// Row-sweep triangular solves with the inner loop over the RHS
    /// columns — fully vectorised (the per-column variant runs scalar and
    /// is ~7× slower at n ≈ 1000 on this testbed; see EXPERIMENTS.md §Perf).
    pub fn solve_mat(&self, b: &Mat<T>) -> Mat<T> {
        let n = self.n();
        assert_eq!(b.rows(), n);
        let t = b.cols();
        let mut x = b.clone();
        // forward: L Y = B
        for i in 0..n {
            let lrow = self.l.row(i);
            // x[i,:] -= Σ_{j<i} L[i,j]·x[j,:]
            for j in 0..i {
                let lij = lrow[j];
                if lij == T::ZERO {
                    continue;
                }
                let (head, tail) = x.data_mut().split_at_mut(i * t);
                let xj = &head[j * t..(j + 1) * t];
                let xi = &mut tail[..t];
                for c in 0..t {
                    xi[c] -= lij * xj[c];
                }
            }
            let inv = T::ONE / lrow[i];
            for v in x.row_mut(i) {
                *v *= inv;
            }
        }
        // backward: Lᵀ X = Y
        for i in (0..n).rev() {
            for j in (i + 1)..n {
                let lji = self.l.get(j, i);
                if lji == T::ZERO {
                    continue;
                }
                let (head, tail) = x.data_mut().split_at_mut(j * t);
                let xi = &mut head[i * t..(i + 1) * t];
                let xj = &tail[..t];
                for c in 0..t {
                    xi[c] -= lji * xj[c];
                }
            }
            let inv = T::ONE / self.l.get(i, i);
            for v in x.row_mut(i) {
                *v *= inv;
            }
        }
        let _ = par::num_threads();
        x
    }

    /// log|A| = 2 Σ log L[i,i].
    pub fn logdet(&self) -> f64 {
        (0..self.n())
            .map(|i| self.l.get(i, i).to_f64().ln())
            .sum::<f64>()
            * 2.0
    }

    /// Solve `L y = b` only (half-solve), used for whitening.
    pub fn forward_solve(&self, b: &[T]) -> Vec<T> {
        let n = self.n();
        let mut x = b.to_vec();
        for i in 0..n {
            let mut s = x[i];
            let row = self.l.row(i);
            for j in 0..i {
                s -= row[j] * x[j];
            }
            x[i] = s / row[i];
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// random SPD matrix A = GᵀG + n·I
    fn spd(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = g.t_matmul(&g);
        a.add_diag(n as f64);
        a.symmetrize();
        a
    }

    #[test]
    fn factor_reconstructs() {
        for &n in &[1, 2, 5, 33, 100, 150] {
            let a = spd(n, n as u64);
            let ch = Cholesky::new(&a).unwrap();
            let recon = ch.l().matmul_t(ch.l());
            assert!(recon.max_abs_diff(&a) < 1e-8 * n as f64, "n={n}");
        }
    }

    #[test]
    fn solve_matches_identity() {
        let n = 60;
        let a = spd(n, 3);
        let ch = Cholesky::new(&a).unwrap();
        let mut rng = Rng::new(9);
        let b: Vec<f64> = rng.normal_vec(n);
        let x = ch.solve_vec(&b);
        let ax = a.matvec(&x);
        for i in 0..n {
            assert!((ax[i] - b[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn solve_mat_matches_solve_vec() {
        let n = 40;
        let a = spd(n, 4);
        let ch = Cholesky::new(&a).unwrap();
        let mut rng = Rng::new(10);
        let b = Mat::from_fn(n, 5, |_, _| rng.normal());
        let x = ch.solve_mat(&b);
        for c in 0..5 {
            let xc = ch.solve_vec(&b.col(c));
            for r in 0..n {
                assert!((x.get(r, c) - xc[r]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn logdet_matches_eigen_free_reference() {
        // 2x2 with known determinant
        let a = Mat::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]);
        let ch = Cholesky::new(&a).unwrap();
        assert!((ch.logdet() - (11.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(Cholesky::new(&a).is_err());
    }

    #[test]
    fn jitter_rescues_near_singular() {
        // rank-1 PSD matrix (singular)
        let v = [1.0, 2.0, 3.0];
        let a = Mat::from_fn(3, 3, |r, c| v[r] * v[c]);
        let ch = Cholesky::new_with_jitter(&a).unwrap();
        assert!(ch.jitter > 0.0);
    }

    #[test]
    fn f32_factor_works() {
        let a64 = spd(30, 8);
        let a: Mat<f32> = a64.cast();
        let ch = Cholesky::new(&a).unwrap();
        let recon = ch.l().matmul_t(ch.l());
        assert!(recon.cast::<f64>().max_abs_diff(&a64) < 1e-2);
    }
}
