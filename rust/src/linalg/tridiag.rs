//! Symmetric tridiagonal eigensolver (implicit QL with Wilkinson shifts —
//! the classic EISPACK `tql2` routine), used to evaluate the stochastic
//! Lanczos quadrature term `e₁ᵀ f(T̃) e₁ = Σᵢ (V₁ᵢ)² f(λᵢ)` (paper eq. 6,
//! App. B: O(p²)–O(p³) for a p×p tridiagonal — negligible next to mBCG).

/// Eigendecomposition of a symmetric tridiagonal matrix.
pub struct SymTridiagEig {
    /// eigenvalues in ascending order
    pub eigenvalues: Vec<f64>,
    /// first components of the (orthonormal) eigenvectors, aligned with
    /// `eigenvalues` — all SLQ needs
    pub first_components: Vec<f64>,
}

impl SymTridiagEig {
    /// Decompose the tridiagonal with diagonal `diag` (len p) and
    /// off-diagonal `offdiag` (len p−1).
    pub fn new(diag: &[f64], offdiag: &[f64]) -> SymTridiagEig {
        let n = diag.len();
        assert!(n > 0, "empty tridiagonal");
        assert_eq!(offdiag.len(), n - 1, "offdiag must have length p-1");
        let mut d = diag.to_vec();
        // e is padded: e[i] couples i and i+1; e[n-1] unused
        let mut e = vec![0.0f64; n];
        e[..n - 1].copy_from_slice(offdiag);

        // We only need the first row of the eigenvector matrix. Initialise
        // z = e₁ᵀ and apply every rotation to it (tql2 specialised to one row).
        let mut z = vec![0.0f64; n];
        z[0] = 1.0;

        for l in 0..n {
            let mut iter = 0;
            loop {
                // find small off-diagonal element
                let mut m = l;
                while m < n - 1 {
                    let dd = d[m].abs() + d[m + 1].abs();
                    if e[m].abs() <= f64::EPSILON * dd {
                        break;
                    }
                    m += 1;
                }
                if m == l {
                    break;
                }
                iter += 1;
                assert!(iter < 50, "tql2 failed to converge");
                // Wilkinson shift
                let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
                let mut r = g.hypot(1.0);
                g = d[m] - d[l] + e[l] / (g + if g >= 0.0 { r.abs() } else { -r.abs() });
                let mut s = 1.0;
                let mut c = 1.0;
                let mut p = 0.0;
                let mut underflow = false;
                for i in (l..m).rev() {
                    let mut f = s * e[i];
                    let b = c * e[i];
                    r = f.hypot(g);
                    e[i + 1] = r;
                    if r == 0.0 {
                        // recover from underflow (NR tqli)
                        d[i + 1] -= p;
                        e[m] = 0.0;
                        underflow = true;
                        break;
                    }
                    s = f / r;
                    c = g / r;
                    g = d[i + 1] - p;
                    r = (d[i] - g) * s + 2.0 * c * b;
                    p = s * r;
                    d[i + 1] = g + p;
                    g = c * r - b;
                    // apply rotation to the tracked first-row vector
                    f = z[i + 1];
                    z[i + 1] = s * z[i] + c * f;
                    z[i] = c * z[i] - s * f;
                }
                if underflow {
                    continue;
                }
                d[l] -= p;
                e[l] = g;
                e[m] = 0.0;
            }
        }

        // sort ascending by eigenvalue, carrying first components
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| d[a].partial_cmp(&d[b]).unwrap());
        let eigenvalues: Vec<f64> = idx.iter().map(|&i| d[i]).collect();
        let first_components: Vec<f64> = idx.iter().map(|&i| z[i]).collect();
        SymTridiagEig {
            eigenvalues,
            first_components,
        }
    }

    /// `e₁ᵀ f(T) e₁ = Σᵢ (V₁ᵢ)² f(λᵢ)` — the SLQ quadrature rule.
    pub fn quadrature(&self, f: impl Fn(f64) -> f64) -> f64 {
        self.eigenvalues
            .iter()
            .zip(self.first_components.iter())
            .map(|(&l, &w)| w * w * f(l))
            .sum()
    }

    /// `e₁ᵀ log(T) e₁` with a floor to guard tiny/negative Ritz values that
    /// arise from finite-precision CG coefficients.
    pub fn log_quadrature(&self) -> f64 {
        self.quadrature(|l| l.max(1e-300).ln())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Mat;
    use crate::util::Rng;

    /// 2x2 analytic check
    #[test]
    fn two_by_two_analytic() {
        // T = [[2, 1], [1, 2]] -> eigenvalues 1, 3; eigvec components 1/√2
        let eig = SymTridiagEig::new(&[2.0, 2.0], &[1.0]);
        assert!((eig.eigenvalues[0] - 1.0).abs() < 1e-12);
        assert!((eig.eigenvalues[1] - 3.0).abs() < 1e-12);
        for &w in &eig.first_components {
            assert!((w.abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-12);
        }
    }

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let d = [3.0, 1.0, 2.0, 5.0];
        let e = [0.0, 0.0, 0.0];
        let eig = SymTridiagEig::new(&d, &e);
        assert_eq!(eig.eigenvalues, vec![1.0, 2.0, 3.0, 5.0]);
        // first eigenvector weight should be 1 on the eigenvalue 3 (index 0)
        let w3 = eig
            .eigenvalues
            .iter()
            .zip(&eig.first_components)
            .find(|(l, _)| (**l - 3.0).abs() < 1e-12)
            .unwrap()
            .1;
        assert!((w3.abs() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn trace_and_det_preserved() {
        let mut rng = Rng::new(1);
        for trial in 0..20 {
            let p = 2 + (trial % 9);
            let diag: Vec<f64> = (0..p).map(|_| 2.0 + rng.uniform() * 3.0).collect();
            let off: Vec<f64> = (0..p - 1).map(|_| rng.uniform() * 0.5).collect();
            let eig = SymTridiagEig::new(&diag, &off);
            let tr: f64 = diag.iter().sum();
            let tr_e: f64 = eig.eigenvalues.iter().sum();
            assert!((tr - tr_e).abs() < 1e-9 * tr.abs());
            // weights sum to 1 (first row of orthonormal V has unit norm)
            let wsum: f64 = eig.first_components.iter().map(|w| w * w).sum();
            assert!((wsum - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn quadrature_matches_dense_matrix_function() {
        // e₁ᵀ log(T) e₁ computed via dense eigen-free reference:
        // build T, compute log(T) via scaling of a spectral decomposition
        // obtained from this very solver on a *full* eigenbasis check:
        // instead validate against Cholesky logdet identity for f=log on a
        // rank-respecting quadrature: Σ wᵢ² λᵢ must equal T[0,0].
        let diag = [4.0, 3.0, 2.5, 5.0];
        let off = [0.8, 0.3, 0.6];
        let eig = SymTridiagEig::new(&diag, &off);
        let t00 = eig.quadrature(|l| l);
        assert!((t00 - 4.0).abs() < 1e-10, "e1' T e1 = {t00}");
        // and Σ wᵢ² λᵢ² must equal (T²)[0,0] = d₀² + e₀²
        let t2_00 = eig.quadrature(|l| l * l);
        assert!((t2_00 - (4.0 * 4.0 + 0.8 * 0.8)).abs() < 1e-9);
    }

    #[test]
    fn logdet_of_full_lanczos_matches_cholesky() {
        // full-rank Lanczos T has the same logdet as A
        let n = 10;
        let mut rng = Rng::new(2);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = g.t_matmul(&g);
        a.add_diag(n as f64);
        let z = rng.normal_vec(n);
        let (t, _q) = crate::linalg::lanczos::lanczos_tridiag(|v| a.matvec(v), &z, n);
        let eig = SymTridiagEig::new(&t.diag, &t.offdiag);
        let ld: f64 = eig.eigenvalues.iter().map(|l| l.ln()).sum();
        let want = crate::linalg::cholesky::Cholesky::new(&a).unwrap().logdet();
        assert!((ld - want).abs() < 1e-7 * want.abs());
    }

    #[test]
    fn single_element() {
        let eig = SymTridiagEig::new(&[7.0], &[]);
        assert_eq!(eig.eigenvalues, vec![7.0]);
        assert!((eig.first_components[0].abs() - 1.0).abs() < 1e-15);
        assert!((eig.log_quadrature() - 7.0f64.ln()).abs() < 1e-12);
    }
}
