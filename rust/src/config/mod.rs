//! Experiment configuration: a TOML-subset parser (offline crate set has
//! no serde/toml) plus the typed [`ExperimentConfig`] the launcher
//! (`bbmm run --config …`) executes. Every figure-regeneration setting
//! can be expressed as a config file — see `configs/*.toml`.

pub mod parser;

pub use parser::{ConfigDoc, ConfigError, Value};

/// Fully-resolved experiment configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    // [dataset]
    pub dataset: String,
    pub n_override: Option<usize>,
    pub csv_path: Option<String>,
    pub seed: u64,
    // [model]
    pub model: String,  // exact | sgpr | ski
    pub kernel: String, // rbf | matern12 | matern32 | matern52
    pub inducing: usize,
    pub noise_init: f64,
    pub lengthscale_init: f64,
    pub outputscale_init: f64,
    // [engine]
    pub engine: String, // bbmm | cholesky | dong
    pub cg_iters: usize,
    pub probes: usize,
    pub precond_rank: usize,
    pub cg_tol: f64,
    // [train]
    pub iters: usize,
    pub lr: f64,
    pub verbose: bool,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            dataset: "wine".into(),
            n_override: None,
            csv_path: None,
            seed: 0,
            model: "exact".into(),
            kernel: "rbf".into(),
            inducing: 300,
            noise_init: 0.1,
            lengthscale_init: 0.5,
            outputscale_init: 1.0,
            engine: "bbmm".into(),
            cg_iters: 20,
            probes: 10,
            precond_rank: 5,
            cg_tol: 1e-10,
            iters: 30,
            lr: 0.1,
            verbose: false,
        }
    }
}

impl ExperimentConfig {
    /// Build from a parsed document; unknown keys are an error (typos must
    /// not silently become defaults).
    pub fn from_doc(doc: &ConfigDoc) -> Result<Self, ConfigError> {
        let mut cfg = ExperimentConfig::default();
        for (section, key, value) in doc.entries() {
            match (section.as_str(), key.as_str()) {
                ("dataset", "name") => cfg.dataset = value.as_str()?.to_string(),
                ("dataset", "n") => cfg.n_override = Some(value.as_usize()?),
                ("dataset", "csv") => cfg.csv_path = Some(value.as_str()?.to_string()),
                ("dataset", "seed") => cfg.seed = value.as_usize()? as u64,
                ("model", "kind") => cfg.model = value.as_str()?.to_string(),
                ("model", "kernel") => cfg.kernel = value.as_str()?.to_string(),
                ("model", "inducing") => cfg.inducing = value.as_usize()?,
                ("model", "noise_init") => cfg.noise_init = value.as_f64()?,
                ("model", "lengthscale_init") => cfg.lengthscale_init = value.as_f64()?,
                ("model", "outputscale_init") => cfg.outputscale_init = value.as_f64()?,
                ("engine", "kind") => cfg.engine = value.as_str()?.to_string(),
                ("engine", "cg_iters") => cfg.cg_iters = value.as_usize()?,
                ("engine", "probes") => cfg.probes = value.as_usize()?,
                ("engine", "precond_rank") => cfg.precond_rank = value.as_usize()?,
                ("engine", "cg_tol") => cfg.cg_tol = value.as_f64()?,
                ("train", "iters") => cfg.iters = value.as_usize()?,
                ("train", "lr") => cfg.lr = value.as_f64()?,
                ("train", "verbose") => cfg.verbose = value.as_bool()?,
                (s, k) => {
                    return Err(ConfigError::new(format!("unknown key [{s}] {k}")));
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_str_toml(text: &str) -> Result<Self, ConfigError> {
        Self::from_doc(&ConfigDoc::parse(text)?)
    }

    pub fn load(path: &std::path::Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError::new(format!("{path:?}: {e}")))?;
        Self::from_str_toml(&text)
    }

    fn validate(&self) -> Result<(), ConfigError> {
        let ok_model = ["exact", "sgpr", "ski"].contains(&self.model.as_str());
        if !ok_model {
            return Err(ConfigError::new(format!("unknown model {:?}", self.model)));
        }
        let ok_kernel =
            ["rbf", "matern12", "matern32", "matern52"].contains(&self.kernel.as_str());
        if !ok_kernel {
            return Err(ConfigError::new(format!("unknown kernel {:?}", self.kernel)));
        }
        let ok_engine = ["bbmm", "cholesky", "dong"].contains(&self.engine.as_str());
        if !ok_engine {
            return Err(ConfigError::new(format!("unknown engine {:?}", self.engine)));
        }
        if self.noise_init <= 0.0 || self.lr <= 0.0 {
            return Err(ConfigError::new("noise_init and lr must be positive"));
        }
        Ok(())
    }

    /// Construct the configured kernel.
    pub fn make_kernel(&self) -> Box<dyn crate::kernels::Kernel> {
        use crate::kernels::{Matern12, Matern32, Matern52, Rbf};
        let (ls, os) = (self.lengthscale_init, self.outputscale_init);
        match self.kernel.as_str() {
            "matern12" => Box::new(Matern12::new(ls, os)),
            "matern32" => Box::new(Matern32::new(ls, os)),
            "matern52" => Box::new(Matern52::new(ls, os)),
            _ => Box::new(Rbf::new(ls, os)),
        }
    }

    /// Construct the configured inference engine.
    pub fn make_engine(&self) -> Box<dyn crate::gp::InferenceEngine> {
        use crate::gp::mll::{BbmmEngine, CholeskyEngine};
        use crate::gp::DongEngine;
        match self.engine.as_str() {
            "cholesky" => Box::new(CholeskyEngine),
            "dong" => Box::new(DongEngine::new(self.cg_iters, self.probes, self.seed)),
            _ => {
                let mut e =
                    BbmmEngine::new(self.cg_iters, self.probes, self.precond_rank, self.seed);
                e.cg_tol = self.cg_tol;
                Box::new(e)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = r#"
# exact GP on wine with BBMM
[dataset]
name = "airfoil"
seed = 3

[model]
kind = "exact"
kernel = "matern52"
noise_init = 0.05

[engine]
kind = "bbmm"
cg_iters = 25
precond_rank = 9

[train]
iters = 40
lr = 0.05
verbose = true
"#;

    #[test]
    fn parses_full_config() {
        let cfg = ExperimentConfig::from_str_toml(EXAMPLE).unwrap();
        assert_eq!(cfg.dataset, "airfoil");
        assert_eq!(cfg.seed, 3);
        assert_eq!(cfg.kernel, "matern52");
        assert_eq!(cfg.noise_init, 0.05);
        assert_eq!(cfg.cg_iters, 25);
        assert_eq!(cfg.precond_rank, 9);
        assert_eq!(cfg.iters, 40);
        assert!(cfg.verbose);
        // untouched fields keep defaults
        assert_eq!(cfg.probes, 10);
    }

    #[test]
    fn rejects_unknown_keys() {
        let err = ExperimentConfig::from_str_toml("[model]\nknd = \"exact\"\n").unwrap_err();
        assert!(err.to_string().contains("unknown key"));
    }

    #[test]
    fn rejects_bad_values() {
        assert!(ExperimentConfig::from_str_toml("[model]\nkind = \"nope\"\n").is_err());
        assert!(ExperimentConfig::from_str_toml("[engine]\nkind = \"x\"\n").is_err());
        assert!(ExperimentConfig::from_str_toml("[train]\nlr = -1.0\n").is_err());
        assert!(ExperimentConfig::from_str_toml("[train]\niters = \"many\"\n").is_err());
    }

    #[test]
    fn factories_build_requested_components() {
        let cfg = ExperimentConfig::from_str_toml(EXAMPLE).unwrap();
        let k = cfg.make_kernel();
        assert_eq!(k.n_params(), 2);
        let e = cfg.make_engine();
        assert_eq!(e.name(), "bbmm");
        let cfg2 = ExperimentConfig::from_str_toml("[engine]\nkind = \"dong\"\n").unwrap();
        assert_eq!(cfg2.make_engine().name(), "dong");
    }
}
