//! Minimal TOML-subset parser: `[sections]`, `key = value` with string /
//! integer / float / boolean values, `#` comments. Enough for experiment
//! configs without the (offline-unavailable) toml crate.

use std::collections::BTreeMap;

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

/// Config parse/typing error.
#[derive(Debug, Clone)]
pub struct ConfigError(String);

impl ConfigError {
    pub fn new(msg: impl Into<String>) -> Self {
        ConfigError(msg.into())
    }
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

impl Value {
    pub fn as_str(&self) -> Result<&str, ConfigError> {
        match self {
            Value::Str(s) => Ok(s),
            other => Err(ConfigError::new(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize, ConfigError> {
        match self {
            Value::Int(i) if *i >= 0 => Ok(*i as usize),
            other => Err(ConfigError::new(format!(
                "expected non-negative integer, got {other:?}"
            ))),
        }
    }

    pub fn as_f64(&self) -> Result<f64, ConfigError> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            other => Err(ConfigError::new(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool, ConfigError> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => Err(ConfigError::new(format!("expected bool, got {other:?}"))),
        }
    }
}

/// Parsed document: ordered (section, key) → value.
#[derive(Debug, Default)]
pub struct ConfigDoc {
    entries: BTreeMap<(String, String), Value>,
    order: Vec<(String, String)>,
}

impl ConfigDoc {
    pub fn parse(text: &str) -> Result<ConfigDoc, ConfigError> {
        let mut doc = ConfigDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(ConfigError::new(format!("line {}: empty section", lineno + 1)));
                }
                continue;
            }
            let (key, value_text) = line.split_once('=').ok_or_else(|| {
                ConfigError::new(format!("line {}: expected key = value", lineno + 1))
            })?;
            let key = key.trim().to_string();
            if key.is_empty() || section.is_empty() {
                return Err(ConfigError::new(format!(
                    "line {}: key/value outside a [section]",
                    lineno + 1
                )));
            }
            let value = parse_value(value_text.trim())
                .map_err(|e| ConfigError::new(format!("line {}: {}", lineno + 1, e.0)))?;
            let entry_key = (section.clone(), key);
            if doc.entries.contains_key(&entry_key) {
                return Err(ConfigError::new(format!(
                    "line {}: duplicate key [{}] {}",
                    lineno + 1,
                    entry_key.0,
                    entry_key.1
                )));
            }
            doc.order.push(entry_key.clone());
            doc.entries.insert(entry_key, value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    /// All entries in file order.
    pub fn entries(&self) -> impl Iterator<Item = (&String, &String, &Value)> {
        self.order
            .iter()
            .map(move |k| (&k.0, &k.1, self.entries.get(k).unwrap()))
    }
}

fn strip_comment(line: &str) -> &str {
    // a # outside quotes starts a comment
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<Value, ConfigError> {
    if text.is_empty() {
        return Err(ConfigError::new("empty value"));
    }
    if let Some(stripped) = text.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| ConfigError::new("unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    match text {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = text.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(ConfigError::new(format!("cannot parse value {text:?}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = ConfigDoc::parse(
            "# comment\n[a]\nx = 1\ny = 2.5\nz = \"hi\"\nw = true\n[b]\nx = -3\n",
        )
        .unwrap();
        assert_eq!(doc.get("a", "x"), Some(&Value::Int(1)));
        assert_eq!(doc.get("a", "y"), Some(&Value::Float(2.5)));
        assert_eq!(doc.get("a", "z"), Some(&Value::Str("hi".into())));
        assert_eq!(doc.get("a", "w"), Some(&Value::Bool(true)));
        assert_eq!(doc.get("b", "x"), Some(&Value::Int(-3)));
        assert_eq!(doc.entries().count(), 5);
    }

    #[test]
    fn inline_comments_and_hash_in_strings() {
        let doc = ConfigDoc::parse("[s]\na = 1 # trailing\nb = \"has # inside\"\n").unwrap();
        assert_eq!(doc.get("s", "a"), Some(&Value::Int(1)));
        assert_eq!(doc.get("s", "b"), Some(&Value::Str("has # inside".into())));
    }

    #[test]
    fn error_cases() {
        assert!(ConfigDoc::parse("x = 1\n").is_err()); // outside section
        assert!(ConfigDoc::parse("[]\n").is_err()); // empty section
        assert!(ConfigDoc::parse("[s]\nnovalue\n").is_err());
        assert!(ConfigDoc::parse("[s]\na = \"unterminated\n").is_err());
        assert!(ConfigDoc::parse("[s]\na = 1\na = 2\n").is_err()); // dup
        assert!(ConfigDoc::parse("[s]\na = what\n").is_err()); // bad value
    }

    #[test]
    fn typed_accessors() {
        let v = Value::Int(5);
        assert_eq!(v.as_usize().unwrap(), 5);
        assert_eq!(v.as_f64().unwrap(), 5.0);
        assert!(v.as_str().is_err());
        assert!(Value::Int(-1).as_usize().is_err());
        assert!(Value::Bool(true).as_bool().unwrap());
    }
}
