//! `bbmm` — CLI for the BBMM GP stack (leader entrypoint).
//!
//! ```text
//! bbmm train   --dataset wine --model exact --engine bbmm --iters 50
//! bbmm predict --dataset airfoil --model exact --engine bbmm
//! bbmm serve   --dataset autompg --model exact|sgpr|ski --addr 127.0.0.1:7777
//! bbmm serve   --tenant wine=exact --tenant fast=sgpr@airfoil   (multi-tenant)
//! bbmm serve   --model exact --backend proc:4      (shards on worker processes)
//! bbmm artifact --name mll_rbf_n256_d4 [--dir artifacts]
//! bbmm info
//! ```
//!
//! Malformed flags print an error + usage hint and exit 2 (they no longer
//! abort the process mid-serve with a panic).

use bbmm_gp::coordinator::{
    multi_served_predictor, multi_served_predictor_fused, multi_served_predictor_love,
    serve_with_love, served_predictor, served_predictor_love, BatchPolicy, DynamicBatcher,
    LoveServeCtx, Metrics, ServableModel, ServerConfig, TenantSpec,
};
use bbmm_gp::data::synthetic::{generate, spec_by_name};
use bbmm_gp::gp::exact::{Engine, ExactGp};
use bbmm_gp::gp::mll::{BatchBbmmEngine, BbmmEngine, CholeskyEngine, InferenceEngine};
use bbmm_gp::gp::predict::{mae, rmse};
use bbmm_gp::gp::{DongEngine, SgprModel, SgprOp, SkiOp};
use bbmm_gp::kernels::{
    DenseKernelOp, KernelCov, KernelCovOp, Matern52, Rbf, ShardedCovOp, ShardedKernelOp,
};
use bbmm_gp::linalg::op::{solve_strategy, AddedDiagOp, LinearOp, SolveOptions, SolvePlanCache};
use bbmm_gp::runtime::dist::{
    BackendSpec, MultiProcessBackend, NumaMode, OutOfCoreBackend, ShmOptions, Transport,
    WorkerLaunch,
};
use bbmm_gp::runtime::{default_artifact_dir, Runtime};
use bbmm_gp::tensor::Mat;
use bbmm_gp::train::{multi_restart_inits, noise_grid_inits, TrainConfig, Trainer};
use bbmm_gp::util::cli::{Args, CliError};
use bbmm_gp::util::{Rng, Timer};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

/// Apply process-wide performance knobs before any operator or pool is
/// built: `--threads N` sizes the persistent worker pool (the flag form of
/// `BBMM_THREADS`), `--mmm-budget-mb M` bounds the kernel materialisation
/// plans (the flag form of `BBMM_MMM_BUDGET_MB`), and `--precision
/// f64|mixed` sets the default tile-compute precision every kernel
/// operator built afterwards inherits (the flag form of `BBMM_PRECISION`).
fn apply_perf_flags(args: &Args) -> Result<(), CliError> {
    if args.get("threads").is_some() {
        bbmm_gp::util::par::set_threads(args.usize_or("threads", 0)?);
    }
    if args.get("mmm-budget-mb").is_some() {
        bbmm_gp::linalg::op::mmm::set_budget_mb(args.usize_or("mmm-budget-mb", 0)?);
    }
    if let Some(p) = args.get("precision") {
        match bbmm_gp::linalg::op::Precision::parse(p) {
            Some(prec) => bbmm_gp::linalg::op::mmm::set_default_precision(prec),
            None => {
                return Err(CliError {
                    flag: "precision".to_string(),
                    message: format!("unknown precision `{p}` (expected f64|mixed)"),
                })
            }
        }
    }
    Ok(())
}

fn main() {
    let args = Args::from_env();
    if let Err(e) = apply_perf_flags(&args) {
        eprintln!("error: {e}");
        eprintln!("run `bbmm help` for usage");
        std::process::exit(2);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "train" => cmd_train(&args),
        "sweep" => cmd_sweep(&args),
        "predict" => cmd_predict(&args),
        "serve" => cmd_serve(&args),
        "bench-serve" => cmd_bench_serve(&args),
        "shard-worker" => cmd_shard_worker(&args),
        "run" => cmd_run(&args),
        "artifact" => {
            cmd_artifact(&args);
            Ok(())
        }
        "info" => {
            cmd_info();
            Ok(())
        }
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        eprintln!("run `bbmm help` for usage");
        std::process::exit(2);
    }
}

/// Launcher: execute an experiment described by a config file
/// (`bbmm run --config configs/exact_airfoil.toml [--mode train|predict]`).
/// The config is translated to the canonical CLI argument set so every
/// option has exactly one meaning across both entry points.
fn cmd_run(args: &Args) -> Result<(), CliError> {
    let Some(path) = args.get("config") else {
        return Err(CliError {
            flag: "config".to_string(),
            message: "bbmm run requires --config <file>".to_string(),
        });
    };
    let cfg = bbmm_gp::config::ExperimentConfig::load(std::path::Path::new(path))
        .unwrap_or_else(|e| panic!("{e}"));
    println!("launch: {path} → {cfg:?}");
    let mut argv: Vec<String> = vec![
        "--dataset".into(),
        cfg.dataset.clone(),
        "--model".into(),
        cfg.model.clone(),
        "--engine".into(),
        cfg.engine.clone(),
        "--kernel".into(),
        cfg.kernel.clone(),
        "--iters".into(),
        cfg.iters.to_string(),
        "--lr".into(),
        cfg.lr.to_string(),
        "--probes".into(),
        cfg.probes.to_string(),
        "--cg-iters".into(),
        cfg.cg_iters.to_string(),
        "--precond-rank".into(),
        cfg.precond_rank.to_string(),
        "--seed".into(),
        cfg.seed.to_string(),
        "--inducing".into(),
        cfg.inducing.to_string(),
    ];
    if let Some(n) = cfg.n_override {
        argv.push("--n".into());
        argv.push(n.to_string());
    }
    if let Some(csv) = &cfg.csv_path {
        argv.push("--csv".into());
        argv.push(csv.clone());
    }
    if cfg.verbose {
        argv.push("--verbose".into());
    }
    let translated = Args::parse(argv);
    match args.get_or("mode", "predict") {
        "train" => cmd_train(&translated),
        "serve" => cmd_serve(&translated),
        _ => cmd_predict(&translated),
    }
}

/// Shard-worker process body — forked by `MultiProcessBackend` (the
/// `--backend proc:N` serve path and the dist tests), not meant for
/// interactive use: connect back to the driver and serve shard products
/// until told to shut down.
fn cmd_shard_worker(args: &Args) -> Result<(), CliError> {
    let Some(addr) = args.get("connect") else {
        return Err(CliError {
            flag: "connect".to_string(),
            message: "bbmm shard-worker requires --connect <addr>".to_string(),
        });
    };
    // NUMA placement: pin before LoadShard so panel pages are
    // first-touched on this worker's node
    if let Some(list) = args.get("pin-cpus") {
        let cpus = bbmm_gp::runtime::dist::shm::parse_cpulist(list);
        if !cpus.is_empty() {
            let _ = bbmm_gp::runtime::dist::shm::pin_to_cpus(&cpus);
        }
    }
    bbmm_gp::runtime::dist::worker::run_worker(addr).map_err(|e| CliError {
        flag: "connect".to_string(),
        message: format!("shard worker failed: {e}"),
    })
}

fn print_help() {
    println!(
        "bbmm — Blackbox Matrix-Matrix GP inference (GPyTorch reproduction)\n\
         \n\
         USAGE: bbmm <command> [options]\n\
         \n\
         COMMANDS:\n\
           train     train GP hyperparameters on a dataset\n\
           sweep     batched multi-restart training: one mBCG call per\n\
                     Adam step across ALL candidates (--restarts R or\n\
                     --noises s1,s2,… for a shared-covariance sweep)\n\
           predict   train then evaluate test MAE/RMSE\n\
           serve     train a model and serve predictions over TCP\n\
           bench-serve  closed-loop serving benchmark: N concurrent TCP\n\
                     clients over a heterogeneous tenant mix (mixed n,\n\
                     mixed family), fused-tick vs per-group-solve servers,\n\
                     parity-gated; writes results/BENCH_serve.json\n\
           shard-worker  (internal) shard-product worker process, forked\n\
                     by --backend proc:N — not for interactive use\n\
           artifact  load + execute an AOT HLO artifact via PJRT\n\
           info      environment / thread / artifact report\n\
         \n\
         COMMON OPTIONS:\n\
           --dataset <name>    paper dataset name (default: wine)\n\
           --model exact|sgpr|ski            (default: exact)\n\
           --engine bbmm|cholesky|dong       (default: bbmm)\n\
           --kernel rbf|matern52             (default: rbf)\n\
           --iters N --lr F --probes T --cg-iters P --precond-rank K\n\
           --seed S --n N (override dataset size)\n\
           --restarts R        (train/sweep: candidate count; train with\n\
                               R > 1 routes to the batched sweep)\n\
           --restart-spread F  (sweep: raw-parameter init perturbation)\n\
           --noises s1,s2,…    (sweep: explicit noise grid — candidates\n\
                               share one covariance, the fused fast path)\n\
           --shards S          (serve: row-shard the kernel operator)\n\
           --backend inproc|proc:N|shm:N|ooc:N   (serve, exact model:\n\
                               where the row shards live and execute — the\n\
                               local thread pool, N forked worker processes\n\
                               speaking the shard wire protocol over TCP,\n\
                               the same fleet with a zero-copy /dev/shm\n\
                               data plane (TCP stays the control plane and\n\
                               the fallback if mapping fails), or an\n\
                               out-of-core spool of N checkpointed kernel\n\
                               panels streamed under a memory budget)\n\
           --numa auto|off     (proc/shm backends: round-robin workers\n\
                               across /sys NUMA nodes and pin them so\n\
                               panels are first-touched on the owning\n\
                               node; auto is a no-op on single-node\n\
                               hosts — default auto)\n\
           --worker-budget-mb M (per-worker materialisation / out-of-core\n\
                               window budget; default --mmm-budget-mb)\n\
           --threads N         (size the persistent worker pool; flag\n\
                               form of BBMM_THREADS)\n\
           --mmm-budget-mb M   (kernel materialisation budget: under it,\n\
                               stationary ops cache the r² panel or K\n\
                               itself; over it they stream tiles — flag\n\
                               form of BBMM_MMM_BUDGET_MB, default 1024)\n\
           --precision f64|mixed  (tile-compute precision: mixed evaluates\n\
                               stationary kernel tiles in f32 — twice the\n\
                               SIMD lane width — while every mBCG\n\
                               reduction accumulates in f64; ~1e-5\n\
                               relative on solves, falls back to full\n\
                               f64 where it cannot apply — flag form of\n\
                               BBMM_PRECISION, default f64)\n\
           --plan-cache-cap N --plan-cache-ttl-s S   (serve: bound the\n\
                               multi-tenant solve-plan cache: LRU + TTL)\n\
           --tenant name=model[@dataset]   (serve: repeatable; host many\n\
                               models behind ONE fused iterative solve per\n\
                               batching tick — mixed sizes and families\n\
                               share the loop — routed by the `name:`\n\
                               line-protocol prefix)\n\
           --grouped           (serve: revert the multi-tenant tick to one\n\
                               solve per distinct training size instead of\n\
                               the fused heterogeneous solve)\n\
           --deadline-ms D     (serve: deadline class for every tenant —\n\
                               requests that cannot meet it are shed with\n\
                               `ERR deadline …` at admission or fast-failed\n\
                               in queue; 0 = no deadlines)\n\
           --tenant-deadline name=ms   (serve: repeatable per-tenant\n\
                               deadline class, overrides --deadline-ms)\n\
           --clients C --requests R    (bench-serve: closed-loop drivers\n\
                               and requests per driver)\n\
           --love-rank R       (serve: LOVE posterior-cache rank, default\n\
                               64 — predictions and the VAR/SAMPLE verbs\n\
                               answer in O(n·R) from cached factors;\n\
                               higher R = tighter variances, exact at R=n)\n\
           --no-love           (serve: disable the LOVE cache and pay a\n\
                               solve per predictive query)"
    );
}

fn make_kernel(args: &Args) -> Box<dyn bbmm_gp::kernels::Kernel> {
    match args.get_or("kernel", "rbf") {
        "matern52" => Box::new(Matern52::new(0.5, 1.0)),
        _ => Box::new(Rbf::new(0.5, 1.0)),
    }
}

fn load_dataset(args: &Args) -> Result<bbmm_gp::data::Dataset, CliError> {
    let name = args.get_or("dataset", "wine");
    let seed = args.u64_or("seed", 0)?;
    if let Some(path) = args.get("csv") {
        return Ok(
            bbmm_gp::data::loader::load_csv(std::path::Path::new(path), name, seed)
                .expect("failed to load csv"),
        );
    }
    let mut spec = spec_by_name(name).unwrap_or_else(|| {
        eprintln!("unknown dataset {name}; using wine");
        spec_by_name("wine").unwrap()
    });
    spec.n = args.usize_or("n", spec.n)?;
    Ok(generate(&spec, seed))
}

fn make_engine(args: &Args) -> Result<Box<dyn InferenceEngine>, CliError> {
    let p = args.usize_or("cg-iters", 20)?;
    let t = args.usize_or("probes", 10)?;
    let k = args.usize_or("precond-rank", 5)?;
    let seed = args.u64_or("seed", 0)?;
    Ok(match args.get_or("engine", "bbmm") {
        "cholesky" => Box::new(CholeskyEngine),
        "dong" => Box::new(DongEngine::new(p, t, seed)),
        _ => Box::new(BbmmEngine::new(p, t, k, seed)),
    })
}

/// Draw `m` inducing points from the training inputs.
fn draw_inducing(ds: &bbmm_gp::data::Dataset, m: usize, seed: u64) -> Mat {
    let m = m.min(ds.n_train());
    let mut rng = Rng::new(seed + 1);
    let mut u = Mat::zeros(m, ds.dim());
    for r in 0..m {
        let src = rng.below(ds.n_train());
        u.row_mut(r).copy_from_slice(ds.x_train.row(src));
    }
    u
}

/// Train the requested model; returns (raw params, final nmll, seconds).
fn train_model(
    args: &Args,
    ds: &bbmm_gp::data::Dataset,
) -> Result<(Vec<f64>, f64, f64), CliError> {
    let mut engine = make_engine(args)?;
    let config = TrainConfig {
        iters: args.usize_or("iters", 30)?,
        lr: args.f64_or("lr", 0.1)?,
        verbose: args.flag("verbose"),
        ..Default::default()
    };
    let timer = Timer::start();
    let model = args.get_or("model", "exact").to_string();
    let y = ds.y_train.clone();
    let (params, nmll) = match model.as_str() {
        "sgpr" => {
            let m = args.usize_or("inducing", 300)?;
            let u = draw_inducing(ds, m, args.u64_or("seed", 0)?);
            let mut op = SgprOp::new(ds.x_train.clone(), u, make_kernel(args), 0.1);
            let mut params = op.params();
            let mut trainer = Trainer::new(config);
            let best = trainer.run(&mut params, |raw| {
                op.set_params(raw);
                engine.mll_and_grad(&op, &y)
            });
            (params, best)
        }
        "ski" => {
            let m = args.usize_or("inducing", 2000)?;
            let z: Vec<f64> = (0..ds.n_train()).map(|i| ds.x_train.row(i)[0]).collect();
            let mut op = SkiOp::new(z, m, make_kernel(args), 0.1);
            let mut params = op.params();
            let mut trainer = Trainer::new(config);
            let best = trainer.run(&mut params, |raw| {
                op.set_params(raw);
                engine.mll_and_grad(&op, &y)
            });
            (params, best)
        }
        _ => {
            let mut op = DenseKernelOp::new(ds.x_train.clone(), make_kernel(args), 0.1);
            let mut params = op.params();
            let mut trainer = Trainer::new(config);
            let best = trainer.run(&mut params, |raw| {
                op.set_params(raw);
                engine.mll_and_grad(&op, &y)
            });
            (params, best)
        }
    };
    Ok((params, nmll, timer.elapsed_s()))
}

fn cmd_train(args: &Args) -> Result<(), CliError> {
    // a multi-restart request is the batched sweep by another name — but
    // the sweep is BBMM-only, so an explicit non-BBMM engine choice must
    // error loudly instead of being silently replaced
    if args.usize_or("restarts", 1)? > 1 || args.get("noises").is_some() {
        return cmd_sweep(args);
    }
    let ds = load_dataset(args)?;
    println!(
        "dataset {} — n_train={} d={} model={} engine={}",
        ds.name,
        ds.n_train(),
        ds.dim(),
        args.get_or("model", "exact"),
        args.get_or("engine", "bbmm")
    );
    let (params, nmll, secs) = train_model(args, &ds)?;
    println!("trained in {secs:.2}s — final nmll {nmll:.4}");
    println!("raw parameters: {params:?}");
    Ok(())
}

/// Batched multi-restart training: R candidates (random restarts or an
/// explicit `--noises` grid sharing one covariance) trained in lockstep —
/// ONE `mbcg_batch` call per Adam step for the whole sweep, per-candidate
/// early stopping, and a winner report.
fn cmd_sweep(args: &Args) -> Result<(), CliError> {
    // the batched sweep is BBMM-only: an explicit non-BBMM engine choice
    // must error loudly instead of being silently replaced
    if args.get_or("engine", "bbmm") != "bbmm" {
        return Err(CliError {
            flag: "engine".to_string(),
            message: format!(
                "the batched sweep (sweep / train --restarts/--noises) is bbmm-only, \
                 got --engine {}",
                args.get_or("engine", "bbmm")
            ),
        });
    }
    let ds = load_dataset(args)?;
    let model = args.get_or("model", "exact").to_string();
    let seed = args.u64_or("seed", 0)?;
    let config = TrainConfig {
        iters: args.usize_or("iters", 30)?,
        lr: args.f64_or("lr", 0.1)?,
        tol: args.f64_or("tol", 0.0)?,
        patience: args.usize_or("patience", 10)?,
        verbose: args.flag("verbose"),
    };
    let mut engine = BatchBbmmEngine::new(
        args.usize_or("cg-iters", 20)?,
        args.usize_or("probes", 10)?,
        args.usize_or("precond-rank", 5)?,
        seed,
    );
    let kernel = make_kernel(args);
    let mut template = kernel.params();
    template.push(0.1f64.ln());
    let noises = args.f64_list_or("noises", &[])?;
    if let Some(&bad) = noises.iter().find(|&&s| !(s > 0.0) || !s.is_finite()) {
        return Err(CliError {
            flag: "noises".to_string(),
            message: format!("noise levels must be positive and finite, got {bad}"),
        });
    }
    let restarts = args.usize_or("restarts", 8)?;
    if noises.is_empty() && restarts == 0 {
        return Err(CliError {
            flag: "restarts".to_string(),
            message: "need at least one restart".to_string(),
        });
    }
    let inits = if noises.is_empty() {
        multi_restart_inits(&template, restarts, args.f64_or("restart-spread", 1.0)?, seed)
    } else {
        noise_grid_inits(&template, &noises)
    };
    println!(
        "sweep: dataset {} n_train={} model={model} candidates={}{}",
        ds.name,
        ds.n_train(),
        inits.len(),
        if noises.is_empty() { "" } else { " (noise grid: fused covariance on shared steps)" }
    );
    let timer = Timer::start();
    let y = ds.y_train.clone();
    let report = match model.as_str() {
        "sgpr" => {
            let m = args.usize_or("inducing", 300)?;
            let u = draw_inducing(&ds, m, seed);
            SgprModel::fit_sweep(&ds.x_train, &y, &u, kernel.as_ref(), &inits, &mut engine, config)
        }
        "exact" => {
            ExactGp::fit_sweep(&ds.x_train, &y, kernel.as_ref(), &inits, &mut engine, config)
        }
        other => {
            return Err(CliError {
                flag: "model".to_string(),
                message: format!("sweep supports exact|sgpr, got {other:?}"),
            })
        }
    };
    let secs = timer.elapsed_s();
    for line in report.summary_lines() {
        println!("{line}");
    }
    println!(
        "swept {} candidates in {secs:.2}s — last step paid {} operator products \
         (sequential equivalent: {}; equal counts mean the candidates' kernels \
         had drifted apart, so no matmul fusion — the win is the single loop + \
         per-candidate early stopping)",
        inits.len(),
        engine.last_stats.batched_products,
        engine.last_stats.system_iterations
    );
    // one timed K̂·M product at the winning parameters: the achieved rate
    // of the streaming compute core under the active precision + dispatch
    {
        let n = ds.n_train();
        let t = args.usize_or("probes", 10)?;
        let mut probe_op = DenseKernelOp::new(ds.x_train.clone(), make_kernel(args), 0.1);
        if let Some(p) = report.best_params() {
            if p.len() == LinearOp::n_params(&probe_op) {
                probe_op.set_params(p);
            }
        }
        let mut rng = Rng::new(seed ^ 0x5eed);
        let m = Mat::from_fn(n, t, |_, _| rng.normal());
        probe_op.prepare();
        let pt = Timer::start();
        let _ = probe_op.matmul(&m);
        let psecs = pt.elapsed_s().max(1e-9);
        let gflops = 2.0 * (n as f64) * (n as f64) * (t as f64) / psecs / 1e9;
        println!(
            "mmm: precision={} simd={} — K̂·M probe ({n}×{n} by {n}×{t}) at {gflops:.2} GFLOP/s",
            bbmm_gp::linalg::op::mmm::default_precision().name(),
            bbmm_gp::tensor::simd::active().name()
        );
    }
    match report.best {
        None => println!("sweep: every candidate diverged — no winner"),
        Some(bi) => {
            println!(
                "winner: candidate {bi} nmll {:.4} params {:?}",
                report.best_nmll().unwrap(),
                report.best_params().unwrap()
            );
            if model == "exact" {
                // evaluate the winning posterior on the held-out split
                let predict_engine = Engine::Bbmm(BbmmEngine::new(
                    args.usize_or("cg-iters", 20)?.max(50),
                    args.usize_or("probes", 10)?,
                    args.usize_or("precond-rank", 5)?,
                    seed,
                ));
                if let Some(mut gp) = ExactGp::from_sweep(
                    ds.x_train.clone(),
                    y.clone(),
                    kernel.as_ref(),
                    &report,
                    predict_engine,
                ) {
                    let pred = gp.predict(&ds.x_test);
                    println!(
                        "winner test MAE {:.4} RMSE {:.4}",
                        mae(&pred.mean, &ds.y_test),
                        rmse(&pred.mean, &ds.y_test)
                    );
                }
            }
        }
    }
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<(), CliError> {
    let ds = load_dataset(args)?;
    let (params, nmll, secs) = train_model(args, &ds)?;
    // evaluate with an exact-GP predictor on the learned hyperparameters
    let engine = match args.get_or("engine", "bbmm") {
        "cholesky" => Engine::Cholesky,
        _ => Engine::Bbmm(BbmmEngine::new(
            args.usize_or("cg-iters", 20)?.max(50),
            args.usize_or("probes", 10)?,
            args.usize_or("precond-rank", 5)?,
            args.u64_or("seed", 0)?,
        )),
    };
    let mut kernel = make_kernel(args);
    let nk = kernel.n_params();
    kernel.set_params(&params[..nk]);
    let noise = params[nk].exp();
    let mut gp = ExactGp::new(ds.x_train.clone(), ds.y_train.clone(), kernel, noise, engine);
    let pred = gp.predict(&ds.x_test);
    println!(
        "dataset {} nmll {:.4} ({secs:.2}s train) test MAE {:.4} RMSE {:.4}",
        ds.name,
        nmll,
        mae(&pred.mean, &ds.y_test),
        rmse(&pred.mean, &ds.y_test)
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Serving adapters: each model family is a few lines of ServableModel glue
// over its operator composition; the server itself is model-agnostic.
// ---------------------------------------------------------------------------

/// Exact GP (monolithic or sharded backend) behind the serving trait.
struct ExactServable {
    op: AddedDiagOp<Box<dyn KernelCov>>,
    y: Vec<f64>,
    /// shard-backend description when the shards execute somewhere other
    /// than the local thread pool (`--backend proc:N` / `ooc:N`)
    backend: Option<String>,
}

impl ServableModel for ExactServable {
    fn op(&self) -> &dyn LinearOp {
        &self.op
    }
    fn cross(&self, xs: &Mat) -> Mat {
        let cov = self.op.inner();
        cov.cross(xs, cov.x())
    }
    fn prior_diag(&self, xs: &Mat) -> Vec<f64> {
        let kernel = self.op.inner().kernel();
        (0..xs.rows()).map(|i| kernel.eval(xs.row(i), xs.row(i))).collect()
    }
    fn y(&self) -> &[f64] {
        &self.y
    }
    fn describe(&self) -> String {
        let base = format!(
            "AddedDiag(KernelCov × {} shards) n={} strategy={:?}",
            self.op.inner().shard_count(),
            self.op.n(),
            solve_strategy(&self.op)
        );
        match &self.backend {
            Some(b) => format!("{base} backend={b}"),
            None => base,
        }
    }
}

/// SGPR behind the serving trait — solves go through the direct Woodbury
/// branch of the dispatcher.
struct SgprServable {
    op: SgprOp,
    y: Vec<f64>,
}

impl ServableModel for SgprServable {
    fn op(&self) -> &dyn LinearOp {
        &self.op
    }
    fn cross(&self, xs: &Mat) -> Mat {
        self.op.cross_sor(xs)
    }
    fn prior_diag(&self, xs: &Mat) -> Vec<f64> {
        let kernel = self.op.kernel();
        (0..xs.rows()).map(|i| kernel.eval(xs.row(i), xs.row(i))).collect()
    }
    fn y(&self) -> &[f64] {
        &self.y
    }
    fn describe(&self) -> String {
        format!(
            "AddedDiag(LowRank(SoR m={})) n={} strategy={:?}",
            self.op.u().rows(),
            self.op.n(),
            solve_strategy(&self.op)
        )
    }
}

/// SKI behind the serving trait (features = first input coordinate, as in
/// the training path).
struct SkiServable {
    op: SkiOp,
    y: Vec<f64>,
}

impl ServableModel for SkiServable {
    fn op(&self) -> &dyn LinearOp {
        &self.op
    }
    fn cross(&self, xs: &Mat) -> Mat {
        let z: Vec<f64> = (0..xs.rows()).map(|i| xs.row(i)[0]).collect();
        self.op.cross(&z)
    }
    fn prior_diag(&self, xs: &Mat) -> Vec<f64> {
        let kernel = self.op.kernel();
        (0..xs.rows())
            .map(|i| {
                let z = [xs.row(i)[0]];
                kernel.eval(&z, &z)
            })
            .collect()
    }
    fn y(&self) -> &[f64] {
        &self.y
    }
    fn describe(&self) -> String {
        let (_lo, _h, m) = self.op.grid();
        format!(
            "AddedDiag(Interp(GridToeplitz m={m})) n={} strategy={:?}",
            self.op.n(),
            solve_strategy(&self.op)
        )
    }
}

/// Train + compose the served model for the canonical single-model
/// argument set (the per-tenant launcher reuses this with overridden
/// `--model`/`--dataset`).
fn build_servable(
    args: &Args,
    ds: &bbmm_gp::data::Dataset,
) -> Result<Box<dyn ServableModel>, CliError> {
    let (params, _nmll, _secs) = train_model(args, ds)?;
    let mut kernel = make_kernel(args);
    let nk = kernel.n_params();
    kernel.set_params(&params[..nk]);
    let noise = params[nk].exp();
    let shards = args.usize_or("shards", 1)?;
    // build the served operator composition for the requested model — the
    // server consumes the ServableModel seam, so any LinearOp composition
    // can sit behind it
    Ok(match args.get_or("model", "exact") {
        "sgpr" => {
            let m = args.usize_or("inducing", 300)?;
            let u = draw_inducing(ds, m, args.u64_or("seed", 0)?);
            Box::new(SgprServable {
                op: SgprOp::new(ds.x_train.clone(), u, kernel, noise),
                y: ds.y_train.clone(),
            })
        }
        "ski" => {
            let m = args.usize_or("inducing", 2000)?;
            let z: Vec<f64> = (0..ds.n_train()).map(|i| ds.x_train.row(i)[0]).collect();
            Box::new(SkiServable {
                op: SkiOp::new(z, m, kernel, noise),
                y: ds.y_train.clone(),
            })
        }
        _ => {
            // exact: monolithic or row-sharded covariance backend, sized
            // to traffic with --shards N, and placed with --backend:
            // in-process threads (default), forked worker processes, or an
            // out-of-core panel spool — same numerics on every placement
            let backend = match args.get("backend") {
                None => BackendSpec::InProcess,
                Some(s) => BackendSpec::parse(s).map_err(|message| CliError {
                    flag: "backend".to_string(),
                    message,
                })?,
            };
            let budget_mb = args.usize_or(
                "worker-budget-mb",
                bbmm_gp::linalg::op::mmm::budget_bytes() >> 20,
            )?;
            let (cov, backend_desc): (Box<dyn KernelCov>, Option<String>) = match backend {
                BackendSpec::InProcess if shards > 1 => (
                    Box::new(ShardedCovOp::new(ds.x_train.clone(), kernel, shards)),
                    None,
                ),
                BackendSpec::InProcess => {
                    (Box::new(KernelCovOp::new(ds.x_train.clone(), kernel)), None)
                }
                BackendSpec::MultiProcess { workers } | BackendSpec::Shm { workers } => {
                    // at least one shard per worker; --shards can refine
                    let n_shards = shards.max(workers);
                    let transport = match backend {
                        BackendSpec::Shm { .. } => Transport::Shm(ShmOptions::default()),
                        _ => Transport::Tcp,
                    };
                    let numa = NumaMode::parse(args.get_or("numa", "auto")).map_err(
                        |message| CliError {
                            flag: "numa".to_string(),
                            message,
                        },
                    )?;
                    let proc = MultiProcessBackend::launch_with(
                        ds.x_train.clone(),
                        kernel.as_ref(),
                        noise,
                        n_shards,
                        workers,
                        budget_mb,
                        WorkerLaunch::default(),
                        transport,
                        numa,
                    )
                    .map_err(|e| CliError {
                        flag: "backend".to_string(),
                        message: format!("failed to launch shard workers: {e}"),
                    })?;
                    let desc = proc.describe();
                    let op = ShardedCovOp::new(ds.x_train.clone(), kernel, n_shards)
                        .with_backend(Arc::new(proc));
                    (Box::new(op), Some(desc))
                }
                BackendSpec::OutOfCore { shards: panels } => {
                    let n_shards = shards.max(panels);
                    // the spool generator carries its own kernel instance
                    let mut spool_kernel = make_kernel(args);
                    spool_kernel.set_params(&params[..nk]);
                    let inner =
                        ShardedKernelOp::new(ds.x_train.clone(), spool_kernel, noise, n_shards);
                    let ooc =
                        OutOfCoreBackend::new(inner, budget_mb << 20).map_err(|e| CliError {
                            flag: "backend".to_string(),
                            message: format!("failed to spool out-of-core panels: {e}"),
                        })?;
                    let desc = ooc.describe();
                    let op = ShardedCovOp::new(ds.x_train.clone(), kernel, n_shards)
                        .with_backend(Arc::new(ooc));
                    (Box::new(op), Some(desc))
                }
            };
            Box::new(ExactServable {
                op: AddedDiagOp::new(cov, noise),
                y: ds.y_train.clone(),
                backend: backend_desc,
            })
        }
    })
}

fn cmd_serve(args: &Args) -> Result<(), CliError> {
    let solve_opts = SolveOptions {
        max_iters: args.usize_or("cg-iters", 20)?.max(50),
        tol: 1e-8,
        precond_rank: args.usize_or("precond-rank", 5)?,
    };
    let policy = BatchPolicy {
        max_batch: args.usize_or("max-batch", 64)?,
        max_wait: std::time::Duration::from_millis(args.u64_or("max-wait-ms", 2)?),
        max_queue: args.usize_or("max-queue", 1024)?,
        // --deadline-ms D arms admission control: requests whose deadline
        // cannot be met at the current queue depth are shed with an
        // `ERR deadline …` line instead of queueing doomed work
        default_deadline: match args.u64_or("deadline-ms", 0)? {
            0 => None,
            ms => Some(std::time::Duration::from_millis(ms)),
        },
    };
    // LOVE posterior cache: on by default — predictions (and the VAR /
    // SAMPLE verbs) answer from cached rank-r factors in O(n·r) instead
    // of paying a solve per query. `--no-love` restores the solve path.
    let love_rank = args.usize_or("love-rank", 64)?;
    if love_rank == 0 {
        return Err(CliError {
            flag: "love-rank".to_string(),
            message: "LOVE rank must be positive (use --no-love to disable)".to_string(),
        });
    }
    let love_enabled = !args.flag("no-love");
    let seed = args.u64_or("seed", 0)?;
    let tenant_specs = args.get_all("tenant");
    let (batcher, love_ctx, operator, shard_count, dims) = if tenant_specs.is_empty() {
        // single-model deployment (tenant 0, routing name "default")
        let ds = load_dataset(args)?;
        let dim = ds.dim();
        let model = build_servable(args, &ds)?;
        let operator = model.describe();
        // only the exact backend consumes --shards; record 1 for the
        // others so the deployment log never claims sharding that is not
        // running
        let shard_count = match args.get_or("model", "exact") {
            "sgpr" | "ski" => 1,
            _ => args.usize_or("shards", 1)?.max(1),
        };
        let (predictor, love_ctx) = if love_enabled {
            let m: Arc<dyn ServableModel> = Arc::from(model);
            let ctx = Arc::new(LoveServeCtx::new(
                vec![("default".to_string(), m)],
                love_rank,
                solve_opts,
                Arc::new(bbmm_gp::gp::PosteriorCache::new()),
                seed,
            ));
            (served_predictor_love(Arc::clone(&ctx)), Some(ctx))
        } else {
            (served_predictor(model, solve_opts), None)
        };
        let batcher = Arc::new(DynamicBatcher::new(dim, policy, predictor));
        (batcher, love_ctx, operator, shard_count, vec![dim])
    } else {
        // multi-tenant deployment: every `--tenant name=model[@dataset]`
        // trains its own posterior; each batching tick answers all
        // tenants through one BatchOp dispatch with per-tenant plans
        // cached across predict calls
        let mut specs: Vec<TenantSpec> = Vec::new();
        let mut models: Vec<(String, Box<dyn ServableModel>)> = Vec::new();
        let mut dims = Vec::new();
        let mut described = Vec::new();
        let mut max_shards = 1usize;
        for spec in &tenant_specs {
            let (name, rest) = spec.split_once('=').ok_or_else(|| CliError {
                flag: "tenant".to_string(),
                message: format!("expected name=model[@dataset], got {spec:?}"),
            })?;
            // the routing layer resolves names first-match, and the plan
            // cache keys by name — a duplicate would shadow one tenant and
            // thrash the other's cache slot, so reject it up front
            if specs.iter().any(|s| s.name == name) {
                return Err(CliError {
                    flag: "tenant".to_string(),
                    message: format!("duplicate tenant name {name:?}"),
                });
            }
            let (model_name, dataset) = match rest.split_once('@') {
                Some((m, d)) => (m, Some(d)),
                None => (rest, None),
            };
            // build_servable's match falls back to exact for unknown
            // names (the single-model path's historic behavior) — here
            // the name is part of a spec string, so a typo like `sgrp`
            // must not silently serve an O(n²) exact posterior
            if !matches!(model_name, "exact" | "sgpr" | "ski") {
                return Err(CliError {
                    flag: "tenant".to_string(),
                    message: format!(
                        "unknown model {model_name:?} in {spec:?} (expected exact|sgpr|ski)"
                    ),
                });
            }
            let mut overrides = vec![("model", model_name)];
            if let Some(d) = dataset {
                overrides.push(("dataset", d));
            }
            let targs = args.with_overrides(&overrides);
            let ds = load_dataset(&targs)?;
            println!(
                "tenant {name}: model={model_name} dataset={} n={} d={}",
                ds.name,
                ds.n_train(),
                ds.dim()
            );
            let model = build_servable(&targs, &ds)?;
            described.push(format!("{name}={}", model.describe()));
            specs.push(TenantSpec::new(name, ds.dim()));
            dims.push(ds.dim());
            models.push((name.to_string(), model));
            // only exact tenants consume --shards (build_servable reads it)
            if !matches!(model_name, "sgpr" | "ski") {
                max_shards = max_shards.max(targs.usize_or("shards", 1)?);
            }
        }
        // per-tenant deadline classes: `--tenant-deadline name=ms`
        // (repeatable) overrides the policy-wide --deadline-ms for that
        // tenant's requests
        for td in args.get_all("tenant-deadline") {
            let err = |message: String| CliError {
                flag: "tenant-deadline".to_string(),
                message,
            };
            let (name, ms) = td
                .split_once('=')
                .ok_or_else(|| err(format!("expected name=ms, got {td:?}")))?;
            let ms: u64 = ms
                .trim()
                .parse()
                .map_err(|e| err(format!("bad deadline in {td:?}: {e}")))?;
            let spec = specs
                .iter_mut()
                .find(|s| s.name == name)
                .ok_or_else(|| err(format!("unknown tenant {name:?}")))?;
            spec.deadline = Some(std::time::Duration::from_millis(ms));
        }
        let cap = args.usize_or("plan-cache-cap", 0)?;
        let ttl_s = args.f64_or("plan-cache-ttl-s", 0.0)?;
        let metrics = Arc::new(Metrics::new());
        let (predictor, love_ctx) = if love_enabled {
            let arcs: Vec<(String, Arc<dyn ServableModel>)> = models
                .into_iter()
                .map(|(name, m)| (name, Arc::from(m) as Arc<dyn ServableModel>))
                .collect();
            let ctx = Arc::new(LoveServeCtx::new(
                arcs,
                love_rank,
                solve_opts,
                Arc::new(bbmm_gp::gp::PosteriorCache::new()),
                seed,
            ));
            (multi_served_predictor_love(Arc::clone(&ctx)), Some(ctx))
        } else {
            let cache = Arc::new(SolvePlanCache::with_policy(
                (cap > 0).then_some(cap),
                (ttl_s > 0.0).then(|| std::time::Duration::from_secs_f64(ttl_s)),
            ));
            // the heterogeneous hot path: ONE fused iterative solve per
            // tick across every tenant (mixed n, mixed family), counted on
            // the shared metrics; --grouped restores one solve per
            // distinct n per tick
            let p = if args.flag("grouped") {
                multi_served_predictor(models, solve_opts, cache)
            } else {
                multi_served_predictor_fused(models, solve_opts, cache, Arc::clone(&metrics))
            };
            (p, None)
        };
        let batcher = Arc::new(DynamicBatcher::new_multi_with_metrics(
            specs, policy, predictor, metrics,
        ));
        (batcher, love_ctx, described.join(" | "), max_shards, dims)
    };
    let config = ServerConfig {
        addr: args.get_or("addr", "127.0.0.1:7777").to_string(),
        operator,
        shard_count,
        stop: Arc::new(AtomicBool::new(false)),
    };
    println!(
        "serving GP predictions (feature dims {dims:?}) — operator: {}",
        config.operator
    );
    match &love_ctx {
        Some(ctx) => {
            // prime every tenant's posterior before the socket binds: the
            // first request pays two skinny GEMMs, not a factorisation
            ctx.prime();
            println!(
                "love: rank={} ({} tenant posteriors primed; VAR/SAMPLE enabled)",
                ctx.rank(),
                ctx.tenant_count()
            )
        }
        None => println!("love: disabled (per-query solve path; VAR/SAMPLE return ERR)"),
    }
    println!(
        "perf: threads={} mmm-budget={}MB precision={} simd={}",
        bbmm_gp::util::par::num_threads(),
        bbmm_gp::linalg::op::mmm::budget_bytes() / (1024 * 1024),
        bbmm_gp::linalg::op::mmm::default_precision().name(),
        bbmm_gp::tensor::simd::active().name()
    );
    serve_with_love(config, batcher, love_ctx, |addr| println!("listening on {addr}"))
        .expect("server failed");
    Ok(())
}

// ---------------------------------------------------------------------------
// bench-serve: closed-loop TCP benchmark over a heterogeneous tenant mix.
// ---------------------------------------------------------------------------

/// Synthetic inputs/targets for one bench tenant (d = 3).
fn bench_serve_xy(n: usize, seed: u64) -> (Mat, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, 3, |_, _| rng.uniform_in(-1.0, 1.0));
    let y: Vec<f64> = (0..n)
        .map(|i| (3.0 * x.get(i, 0)).sin() - 0.5 * x.get(i, 1) + 0.3 * x.get(i, 2))
        .collect();
    (x, y)
}

/// The heterogeneous tenant mix: two exact tenants with different
/// training sizes plus an SGPR tenant (Woodbury direct plan) — ≥2 sizes
/// AND ≥2 model families, so a mixed tick exercises the fused path's full
/// generality. Deterministic, so every call builds identical models.
fn bench_serve_models(quick: bool) -> Vec<(String, Box<dyn ServableModel>)> {
    let (n_small, n_large, n_sgpr) = if quick { (120, 240, 160) } else { (240, 480, 320) };
    let exact = |n: usize, seed: u64, matern: bool| -> Box<dyn ServableModel> {
        let (x, y) = bench_serve_xy(n, seed);
        let kernel: Box<dyn bbmm_gp::kernels::Kernel> = if matern {
            Box::new(Matern52::new(0.6, 0.9))
        } else {
            Box::new(Rbf::new(0.5, 1.0))
        };
        let cov: Box<dyn KernelCov> = Box::new(KernelCovOp::new(x, kernel));
        Box::new(ExactServable {
            op: AddedDiagOp::new(cov, 0.05),
            y,
            backend: None,
        })
    };
    let sgpr = |n: usize, seed: u64| -> Box<dyn ServableModel> {
        let (x, y) = bench_serve_xy(n, seed);
        let mut rng = Rng::new(seed + 7);
        let m = 40.min(n);
        let mut u = Mat::zeros(m, 3);
        for r in 0..m {
            u.row_mut(r).copy_from_slice(x.row(rng.below(n)));
        }
        Box::new(SgprServable {
            op: SgprOp::new(x, u, Box::new(Rbf::new(0.5, 1.0)), 0.1),
            y,
        })
    };
    vec![
        ("small".to_string(), exact(n_small, 11, false)),
        ("large".to_string(), exact(n_large, 22, true)),
        ("sgpr".to_string(), sgpr(n_sgpr, 33)),
    ]
}

/// One closed-loop run: serve the tenant mix with the given predictor
/// flavour, drive it with `clients` concurrent TCP clients of `requests`
/// requests each (round-robin over tenants), and report rates.
struct ServeRun {
    qps: f64,
    p50_us: f64,
    p99_us: f64,
    ticks: u64,
    fused_ticks: u64,
    fused_blocks: u64,
}

fn run_serve_loop(fused: bool, quick: bool, clients: usize, requests: usize) -> ServeRun {
    use std::io::{BufRead, BufReader, Write};
    use std::sync::atomic::Ordering;
    let opts = SolveOptions {
        max_iters: 400,
        tol: 1e-10,
        precond_rank: 5,
    };
    let models = bench_serve_models(quick);
    let specs: Vec<TenantSpec> =
        models.iter().map(|(name, _)| TenantSpec::new(name.clone(), 3)).collect();
    let metrics = Arc::new(Metrics::new());
    let cache = Arc::new(SolvePlanCache::new());
    let predictor = if fused {
        multi_served_predictor_fused(models, opts, cache, Arc::clone(&metrics))
    } else {
        multi_served_predictor(models, opts, cache)
    };
    let policy = BatchPolicy {
        max_batch: 64,
        max_wait: std::time::Duration::from_millis(1),
        ..BatchPolicy::default()
    };
    let batcher = Arc::new(DynamicBatcher::new_multi_with_metrics(
        specs, policy, predictor, metrics,
    ));
    let stop = Arc::new(AtomicBool::new(false));
    let config = ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        operator: String::new(),
        shard_count: 1,
        stop: Arc::clone(&stop),
    };
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let srv = {
        let b = Arc::clone(&batcher);
        std::thread::spawn(move || {
            serve_with_love(config, b, None, move |addr| {
                addr_tx.send(addr).unwrap();
            })
            .unwrap();
        })
    };
    let addr = addr_rx.recv().unwrap();
    let lines = [
        "small:0.2,-0.4,0.1\n".to_string(),
        "large:-0.3,0.5,0.2\n".to_string(),
        "sgpr:0.1,0.3,-0.5\n".to_string(),
    ];
    let timer = Timer::start();
    let mut drivers = Vec::new();
    for c in 0..clients {
        let lines = lines.clone();
        drivers.push(std::thread::spawn(move || {
            let conn = std::net::TcpStream::connect(addr).unwrap();
            let mut writer = conn.try_clone().unwrap();
            let mut reader = BufReader::new(conn);
            for r in 0..requests {
                writer.write_all(lines[(c + r) % lines.len()].as_bytes()).unwrap();
                let mut resp = String::new();
                reader.read_line(&mut resp).unwrap();
                assert!(!resp.starts_with("ERR"), "serve error: {resp}");
            }
        }));
    }
    for d in drivers {
        d.join().unwrap();
    }
    let elapsed = timer.elapsed_s().max(1e-9);
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    srv.join().unwrap();
    let m = &batcher.metrics;
    ServeRun {
        qps: (clients * requests) as f64 / elapsed,
        p50_us: m.quantile_latency_us(0.5),
        p99_us: m.quantile_latency_us(0.99),
        ticks: m.batches.load(Ordering::Relaxed),
        fused_ticks: m.fused_solves.load(Ordering::Relaxed),
        fused_blocks: m.fused_blocks.load(Ordering::Relaxed),
    }
}

/// `bbmm bench-serve`: parity-gate the fused heterogeneous tick against
/// the per-group-solve tick on identical mixed blocks, then drive both
/// servers closed-loop over TCP and report QPS + the fused-vs-grouped
/// speedup. Writes `results/BENCH_serve.json` (gated in CI against
/// `rust/benches/BENCH_serve_baseline.json`).
fn cmd_bench_serve(args: &Args) -> Result<(), CliError> {
    use bbmm_gp::coordinator::TenantBatch;
    let quick = args.flag("quick") || std::env::var("BBMM_BENCH_QUICK").is_ok();
    let clients = args.usize_or("clients", if quick { 4 } else { 8 })?;
    let requests = args.usize_or("requests", if quick { 50 } else { 250 })?;
    let opts = SolveOptions {
        max_iters: 400,
        tol: 1e-10,
        precond_rank: 5,
    };

    // parity gate BEFORE timing: the fused tick must reproduce the
    // per-group tick on an identical mixed-tenant block set
    let fused_p = multi_served_predictor_fused(
        bench_serve_models(quick),
        opts,
        Arc::new(SolvePlanCache::new()),
        Arc::new(Metrics::new()),
    );
    let grouped_p = multi_served_predictor(
        bench_serve_models(quick),
        opts,
        Arc::new(SolvePlanCache::new()),
    );
    let probes = [
        vec![0.2, -0.4, 0.1],
        vec![-0.3, 0.5, 0.2],
        vec![0.1, 0.3, -0.5],
    ];
    let blocks: Vec<TenantBatch> = probes
        .iter()
        .enumerate()
        .map(|(t, p)| TenantBatch {
            tenant: t,
            xs: Mat::from_vec(1, 3, p.clone()),
        })
        .collect();
    let want = grouped_p(&blocks);
    let got = fused_p(&blocks);
    for (t, (g, w)) in got.iter().zip(&want).enumerate() {
        for (a, b) in g.mean.iter().zip(&w.mean).chain(g.var.iter().zip(&w.var)) {
            let rel = (a - b).abs() / b.abs().max(1e-12);
            assert!(rel < 1e-8, "tenant {t}: fused/grouped diverged ({a} vs {b})");
        }
    }
    println!("parity: fused tick matches per-group tick on a mixed block set (<1e-8 rel)");

    println!(
        "bench-serve: clients={clients} requests={requests} quick={quick} \
         tenants=small(exact)+large(exact)+sgpr"
    );
    let grouped = run_serve_loop(false, quick, clients, requests);
    let fused = run_serve_loop(true, quick, clients, requests);
    assert!(fused.fused_ticks > 0, "fused run recorded no fused solves");
    let speedup = fused.qps / grouped.qps.max(1e-9);
    println!(
        "grouped: {:.0} qps p50={:.0}us p99={:.0}us ticks={}",
        grouped.qps, grouped.p50_us, grouped.p99_us, grouped.ticks
    );
    println!(
        "fused:   {:.0} qps p50={:.0}us p99={:.0}us ticks={} \
         fused_ticks={} mean_occupancy={:.2} blocks/tick",
        fused.qps,
        fused.p50_us,
        fused.p99_us,
        fused.ticks,
        fused.fused_ticks,
        fused.fused_blocks as f64 / fused.fused_ticks.max(1) as f64
    );
    println!("fused-vs-grouped speedup: {speedup:.2}x");

    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"serve\",\n");
    out.push_str(
        "  \"comment\": \"closed-loop TCP serving over a heterogeneous tenant mix \
         (two exact sizes + sgpr); fused = one iterative solve per tick across all \
         tenants, grouped = one solve per distinct training size; parity-gated \
         before timing\",\n",
    );
    out.push_str(&format!("  \"clients\": {clients},\n"));
    out.push_str(&format!("  \"requests_per_client\": {requests},\n"));
    out.push_str("  \"cases\": [\n");
    out.push_str(&format!(
        "    {{\"name\": \"grouped\", \"qps\": {:.2}, \"p50_us\": {:.0}, \
         \"p99_us\": {:.0}, \"ticks\": {}}},\n",
        grouped.qps, grouped.p50_us, grouped.p99_us, grouped.ticks
    ));
    out.push_str(&format!(
        "    {{\"name\": \"fused\", \"qps\": {:.2}, \"p50_us\": {:.0}, \
         \"p99_us\": {:.0}, \"ticks\": {}, \"fused_ticks\": {}, \
         \"fused_blocks\": {}, \"speedup\": {:.3}}}\n",
        fused.qps, fused.p50_us, fused.p99_us, fused.ticks, fused.fused_ticks,
        fused.fused_blocks, speedup
    ));
    out.push_str("  ]\n");
    out.push_str("}\n");
    std::fs::create_dir_all("results").expect("create results dir");
    std::fs::write("results/BENCH_serve.json", out).expect("write BENCH_serve.json");
    println!("wrote results/BENCH_serve.json");
    Ok(())
}

fn cmd_artifact(args: &Args) {
    let dir = args
        .get("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifact_dir);
    let mut rt = Runtime::cpu(&dir).expect("pjrt init");
    match args.get("name") {
        None => println!("available artifacts in {dir:?}: {:?}", rt.available()),
        Some(name) => {
            rt.load(name).expect("load artifact");
            println!("loaded + compiled {name} on {}", rt.platform());
            println!("run `cargo run --release --example quickstart` for an end-to-end execution");
        }
    }
}

fn cmd_info() {
    println!("bbmm-gp — BBMM reproduction (GPyTorch, NeurIPS 2018)");
    println!("threads: {}", bbmm_gp::util::par::num_threads());
    let dir = default_artifact_dir();
    match Runtime::cpu(&dir) {
        Ok(rt) => println!(
            "pjrt platform: {} — artifacts in {dir:?}: {:?}",
            rt.platform(),
            rt.available()
        ),
        Err(e) => println!("pjrt unavailable: {e}"),
    }
}
