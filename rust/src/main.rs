//! `bbmm` — CLI for the BBMM GP stack (leader entrypoint).
//!
//! ```text
//! bbmm train   --dataset wine --model exact --engine bbmm --iters 50
//! bbmm predict --dataset airfoil --model exact --engine bbmm
//! bbmm serve   --dataset autompg --addr 127.0.0.1:7777
//! bbmm artifact --name mll_rbf_n256_d4 [--dir artifacts]
//! bbmm info
//! ```

use bbmm_gp::coordinator::{serve, BatchPolicy, DynamicBatcher, PredictFn, ServerConfig};
use bbmm_gp::data::synthetic::{generate, spec_by_name};
use bbmm_gp::gp::exact::{Engine, ExactGp};
use bbmm_gp::gp::mll::{BbmmEngine, CholeskyEngine, InferenceEngine};
use bbmm_gp::gp::predict::{mae, rmse};
use bbmm_gp::gp::{DongEngine, SgprOp, SkiOp};
use bbmm_gp::kernels::{DenseKernelOp, Matern52, Rbf};
use bbmm_gp::runtime::{default_artifact_dir, Runtime};
use bbmm_gp::tensor::Mat;
use bbmm_gp::train::{TrainConfig, Trainer};
use bbmm_gp::util::cli::Args;
use bbmm_gp::util::{Rng, Timer};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "predict" => cmd_predict(&args),
        "serve" => cmd_serve(&args),
        "run" => cmd_run(&args),
        "artifact" => cmd_artifact(&args),
        "info" => cmd_info(),
        _ => print_help(),
    }
}

/// Launcher: execute an experiment described by a config file
/// (`bbmm run --config configs/exact_airfoil.toml [--mode train|predict]`).
/// The config is translated to the canonical CLI argument set so every
/// option has exactly one meaning across both entry points.
fn cmd_run(args: &Args) {
    let path = args
        .get("config")
        .expect("bbmm run requires --config <file>");
    let cfg = bbmm_gp::config::ExperimentConfig::load(std::path::Path::new(path))
        .unwrap_or_else(|e| panic!("{e}"));
    println!("launch: {path} → {cfg:?}");
    let mut argv: Vec<String> = vec![
        "--dataset".into(),
        cfg.dataset.clone(),
        "--model".into(),
        cfg.model.clone(),
        "--engine".into(),
        cfg.engine.clone(),
        "--kernel".into(),
        cfg.kernel.clone(),
        "--iters".into(),
        cfg.iters.to_string(),
        "--lr".into(),
        cfg.lr.to_string(),
        "--probes".into(),
        cfg.probes.to_string(),
        "--cg-iters".into(),
        cfg.cg_iters.to_string(),
        "--precond-rank".into(),
        cfg.precond_rank.to_string(),
        "--seed".into(),
        cfg.seed.to_string(),
        "--inducing".into(),
        cfg.inducing.to_string(),
    ];
    if let Some(n) = cfg.n_override {
        argv.push("--n".into());
        argv.push(n.to_string());
    }
    if let Some(csv) = &cfg.csv_path {
        argv.push("--csv".into());
        argv.push(csv.clone());
    }
    if cfg.verbose {
        argv.push("--verbose".into());
    }
    let translated = Args::parse(argv);
    match args.get_or("mode", "predict") {
        "train" => cmd_train(&translated),
        "serve" => cmd_serve(&translated),
        _ => cmd_predict(&translated),
    }
}

fn print_help() {
    println!(
        "bbmm — Blackbox Matrix-Matrix GP inference (GPyTorch reproduction)\n\
         \n\
         USAGE: bbmm <command> [options]\n\
         \n\
         COMMANDS:\n\
           train     train GP hyperparameters on a dataset\n\
           predict   train then evaluate test MAE/RMSE\n\
           serve     train a model and serve predictions over TCP\n\
           artifact  load + execute an AOT HLO artifact via PJRT\n\
           info      environment / thread / artifact report\n\
         \n\
         COMMON OPTIONS:\n\
           --dataset <name>    paper dataset name (default: wine)\n\
           --model exact|sgpr|ski            (default: exact)\n\
           --engine bbmm|cholesky|dong       (default: bbmm)\n\
           --kernel rbf|matern52             (default: rbf)\n\
           --iters N --lr F --probes T --cg-iters P --precond-rank K\n\
           --seed S --n N (override dataset size)\n\
           --shards S          (serve: row-shard the kernel operator)"
    );
}

fn make_kernel(args: &Args) -> Box<dyn bbmm_gp::kernels::Kernel> {
    match args.get_or("kernel", "rbf") {
        "matern52" => Box::new(Matern52::new(0.5, 1.0)),
        _ => Box::new(Rbf::new(0.5, 1.0)),
    }
}

fn load_dataset(args: &Args) -> bbmm_gp::data::Dataset {
    let name = args.get_or("dataset", "wine");
    let seed = args.u64_or("seed", 0);
    if let Some(path) = args.get("csv") {
        return bbmm_gp::data::loader::load_csv(std::path::Path::new(path), name, seed)
            .expect("failed to load csv");
    }
    let mut spec = spec_by_name(name).unwrap_or_else(|| {
        eprintln!("unknown dataset {name}; using wine");
        spec_by_name("wine").unwrap()
    });
    if let Some(n) = args.get("n") {
        spec.n = n.parse().expect("--n must be an integer");
    }
    generate(&spec, seed)
}

fn make_engine(args: &Args) -> Box<dyn InferenceEngine> {
    let p = args.usize_or("cg-iters", 20);
    let t = args.usize_or("probes", 10);
    let k = args.usize_or("precond-rank", 5);
    let seed = args.u64_or("seed", 0);
    match args.get_or("engine", "bbmm") {
        "cholesky" => Box::new(CholeskyEngine),
        "dong" => Box::new(DongEngine::new(p, t, seed)),
        _ => Box::new(BbmmEngine::new(p, t, k, seed)),
    }
}

/// Train the requested model; returns (raw params, final nmll, seconds).
fn train_model(args: &Args, ds: &bbmm_gp::data::Dataset) -> (Vec<f64>, f64, f64) {
    let mut engine = make_engine(args);
    let config = TrainConfig {
        iters: args.usize_or("iters", 30),
        lr: args.f64_or("lr", 0.1),
        verbose: args.flag("verbose"),
        ..Default::default()
    };
    let timer = Timer::start();
    let model = args.get_or("model", "exact").to_string();
    let y = ds.y_train.clone();
    let (params, nmll) = match model.as_str() {
        "sgpr" => {
            let m = args.usize_or("inducing", 300).min(ds.n_train());
            let mut rng = Rng::new(args.u64_or("seed", 0) + 1);
            let mut u = Mat::zeros(m, ds.dim());
            for r in 0..m {
                let src = rng.below(ds.n_train());
                u.row_mut(r).copy_from_slice(ds.x_train.row(src));
            }
            let mut op = SgprOp::new(ds.x_train.clone(), u, make_kernel(args), 0.1);
            let mut params = op.params();
            let mut trainer = Trainer::new(config);
            let best = trainer.run(&mut params, |raw| {
                op.set_params(raw);
                engine.mll_and_grad(&op, &y)
            });
            (params, best)
        }
        "ski" => {
            let m = args.usize_or("inducing", 2000);
            let z: Vec<f64> = (0..ds.n_train()).map(|i| ds.x_train.row(i)[0]).collect();
            let mut op = SkiOp::new(z, m, make_kernel(args), 0.1);
            let mut params = op.params();
            let mut trainer = Trainer::new(config);
            let best = trainer.run(&mut params, |raw| {
                op.set_params(raw);
                engine.mll_and_grad(&op, &y)
            });
            (params, best)
        }
        _ => {
            let mut op = DenseKernelOp::new(ds.x_train.clone(), make_kernel(args), 0.1);
            let mut params = op.params();
            let mut trainer = Trainer::new(config);
            let best = trainer.run(&mut params, |raw| {
                op.set_params(raw);
                engine.mll_and_grad(&op, &y)
            });
            (params, best)
        }
    };
    (params, nmll, timer.elapsed_s())
}

fn cmd_train(args: &Args) {
    let ds = load_dataset(args);
    println!(
        "dataset {} — n_train={} d={} model={} engine={}",
        ds.name,
        ds.n_train(),
        ds.dim(),
        args.get_or("model", "exact"),
        args.get_or("engine", "bbmm")
    );
    let (params, nmll, secs) = train_model(args, &ds);
    println!("trained in {secs:.2}s — final nmll {nmll:.4}");
    println!("raw parameters: {params:?}");
}

fn cmd_predict(args: &Args) {
    let ds = load_dataset(args);
    let (params, nmll, secs) = train_model(args, &ds);
    // evaluate with an exact-GP predictor on the learned hyperparameters
    let engine = match args.get_or("engine", "bbmm") {
        "cholesky" => Engine::Cholesky,
        _ => Engine::Bbmm(BbmmEngine::new(
            args.usize_or("cg-iters", 20).max(50),
            args.usize_or("probes", 10),
            args.usize_or("precond-rank", 5),
            args.u64_or("seed", 0),
        )),
    };
    let mut kernel = make_kernel(args);
    let nk = kernel.n_params();
    kernel.set_params(&params[..nk]);
    let noise = params[nk].exp();
    let mut gp = ExactGp::new(ds.x_train.clone(), ds.y_train.clone(), kernel, noise, engine);
    let pred = gp.predict(&ds.x_test);
    println!(
        "dataset {} nmll {:.4} ({secs:.2}s train) test MAE {:.4} RMSE {:.4}",
        ds.name,
        nmll,
        mae(&pred.mean, &ds.y_test),
        rmse(&pred.mean, &ds.y_test)
    );
}

fn cmd_serve(args: &Args) {
    let ds = load_dataset(args);
    let (params, _nmll, _secs) = train_model(args, &ds);
    let mut kernel = make_kernel(args);
    let nk = kernel.n_params();
    kernel.set_params(&params[..nk]);
    let noise = params[nk].exp();
    let dim = ds.dim();
    // shard the serving operator when asked (--shards N): same numerics,
    // but the hot mat-mul runs over per-shard work queues sized to traffic
    let shards = args.usize_or("shards", 1);
    let engine = Engine::Bbmm(BbmmEngine::default());
    let gp = std::sync::Mutex::new(if shards > 1 {
        ExactGp::new_sharded(
            ds.x_train.clone(),
            ds.y_train.clone(),
            kernel,
            noise,
            engine,
            shards,
        )
    } else {
        ExactGp::new(
            ds.x_train.clone(),
            ds.y_train.clone(),
            kernel,
            noise,
            engine,
        )
    });
    let shard_count = gp.lock().unwrap().op().shard_count();
    let predict: PredictFn = Box::new(move |xs: &Mat| gp.lock().unwrap().predict(xs));
    let batcher = Arc::new(DynamicBatcher::new(
        dim,
        BatchPolicy {
            max_batch: args.usize_or("max-batch", 64),
            max_wait: std::time::Duration::from_millis(args.u64_or("max-wait-ms", 2)),
        },
        predict,
    ));
    let config = ServerConfig {
        addr: args.get_or("addr", "127.0.0.1:7777").to_string(),
        shard_count,
        stop: Arc::new(AtomicBool::new(false)),
    };
    println!(
        "serving {dim}-feature GP predictions (operator shards: {})…",
        config.shard_count
    );
    serve(config, batcher, |addr| println!("listening on {addr}")).expect("server failed");
}

fn cmd_artifact(args: &Args) {
    let dir = args
        .get("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifact_dir);
    let mut rt = Runtime::cpu(&dir).expect("pjrt init");
    match args.get("name") {
        None => println!("available artifacts in {dir:?}: {:?}", rt.available()),
        Some(name) => {
            rt.load(name).expect("load artifact");
            println!("loaded + compiled {name} on {}", rt.platform());
            println!("run `cargo run --release --example quickstart` for an end-to-end execution");
        }
    }
}

fn cmd_info() {
    println!("bbmm-gp — BBMM reproduction (GPyTorch, NeurIPS 2018)");
    println!("threads: {}", bbmm_gp::util::par::num_threads());
    let dir = default_artifact_dir();
    match Runtime::cpu(&dir) {
        Ok(rt) => println!(
            "pjrt platform: {} — artifacts in {dir:?}: {:?}",
            rt.platform(),
            rt.available()
        ),
        Err(e) => println!("pjrt unavailable: {e}"),
    }
}
