//! The training loop: Adam on raw hyperparameters against any objective
//! that returns (nmll, gradient) — i.e. any model × engine pairing.
//!
//! Generic over a closure so the exact GP, SGPR and SKI models (each with a
//! different operator type) all share this loop, as do the BBMM / Cholesky /
//! Dong engines (the Figure 2/3 comparisons swap only the closure).

use crate::gp::mll::MllGrad;
use crate::train::adam::Adam;
use crate::util::Timer;

/// Training configuration (paper §6 defaults).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub iters: usize,
    pub lr: f64,
    /// stop early if nmll improves by less than `tol` over `patience` steps
    pub tol: f64,
    pub patience: usize,
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            iters: 50,
            lr: 0.1,
            tol: 0.0,
            patience: 10,
            verbose: false,
        }
    }
}

/// One row of training history.
#[derive(Debug, Clone)]
pub struct TrainRecord {
    pub iter: usize,
    pub nmll: f64,
    pub grad_norm: f64,
    pub elapsed_s: f64,
    pub cg_iterations: usize,
}

/// Runs Adam over a (params → MllGrad) objective.
pub struct Trainer {
    pub config: TrainConfig,
    pub history: Vec<TrainRecord>,
    /// true once a non-finite nmll/gradient aborted the run — the
    /// optimiser state is left unpoisoned and `params` keep their last
    /// finite value (fail fast instead of walking NaNs for `iters` steps)
    pub diverged: bool,
}

impl Trainer {
    pub fn new(config: TrainConfig) -> Self {
        Trainer {
            config,
            history: Vec::new(),
            diverged: false,
        }
    }

    /// Optimise `params` in place. `objective` must return the nmll and its
    /// gradient at the supplied raw parameters. A non-finite nmll or
    /// gradient stops the run immediately with [`Trainer::diverged`] set.
    pub fn run(
        &mut self,
        params: &mut Vec<f64>,
        mut objective: impl FnMut(&[f64]) -> MllGrad,
    ) -> f64 {
        let mut adam = Adam::new(params.len(), self.config.lr);
        let timer = Timer::start();
        let mut best = f64::INFINITY;
        let mut since_best = 0usize;
        for it in 0..self.config.iters {
            let res = objective(params);
            let gnorm = res.grad.iter().map(|g| g * g).sum::<f64>().sqrt();
            self.history.push(TrainRecord {
                iter: it,
                nmll: res.nmll,
                grad_norm: gnorm,
                elapsed_s: timer.elapsed_s(),
                cg_iterations: res.iterations,
            });
            if self.config.verbose {
                eprintln!(
                    "[train] iter {it:4} nmll {:.6} |g| {:.3e} ({:.2}s)",
                    res.nmll,
                    gnorm,
                    timer.elapsed_s()
                );
            }
            if !res.nmll.is_finite() || !gnorm.is_finite() {
                self.diverged = true;
                if self.config.verbose {
                    eprintln!("[train] iter {it:4} diverged (non-finite nmll/grad) — stopping");
                }
                break;
            }
            if res.nmll < best - self.config.tol {
                best = res.nmll;
                since_best = 0;
            } else {
                since_best += 1;
                if self.config.tol > 0.0 && since_best >= self.config.patience {
                    break;
                }
            }
            if !adam.step_guarded(params, &res.grad) {
                self.diverged = true;
                break;
            }
        }
        best
    }

    /// Final nmll observed.
    pub fn final_nmll(&self) -> f64 {
        self.history.last().map(|r| r.nmll).unwrap_or(f64::NAN)
    }

    /// Total wall-clock training time.
    pub fn total_time_s(&self) -> f64 {
        self.history.last().map(|r| r.elapsed_s).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::mll::{CholeskyEngine, InferenceEngine};
    use crate::kernels::{DenseKernelOp, Rbf};
    use crate::linalg::op::LinearOp;
    use crate::tensor::Mat;
    use crate::util::Rng;

    #[test]
    fn training_improves_nmll_and_recovers_scales() {
        let n = 120;
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(n, 1, |_, _| rng.uniform_in(-1.0, 1.0));
        // data generated with lengthscale ~0.3, noise 0.05
        let y: Vec<f64> = (0..n)
            .map(|i| (x.get(i, 0) / 0.3).sin() + 0.05 * rng.normal())
            .collect();
        // start far away
        let mut op = DenseKernelOp::new(x, Box::new(Rbf::new(3.0, 0.3)), 0.5);
        let mut params = op.params();
        let nmll0 = CholeskyEngine.mll_and_grad(&op, &y).nmll;

        let mut trainer = Trainer::new(TrainConfig {
            iters: 60,
            lr: 0.1,
            ..Default::default()
        });
        let best = trainer.run(&mut params, |raw| {
            op.set_params(raw);
            CholeskyEngine.mll_and_grad(&op, &y)
        });
        assert!(best < nmll0 - 10.0, "nmll {nmll0} -> {best}");
        op.set_params(&params);
        // learned noise should head toward the true 0.05² scale region
        let learned_noise = op.noise();
        assert!(learned_noise < 0.3, "noise={learned_noise}");
        assert_eq!(trainer.history.len(), 60);
    }

    #[test]
    fn non_finite_objective_fails_fast_without_poisoning_params() {
        let mut trainer = Trainer::new(TrainConfig {
            iters: 50,
            lr: 0.1,
            ..Default::default()
        });
        let mut params = vec![1.0, -2.0];
        let mut calls = 0usize;
        let best = trainer.run(&mut params, |_| {
            calls += 1;
            let nmll = if calls >= 3 { f64::NAN } else { 10.0 - calls as f64 };
            MllGrad {
                nmll,
                grad: vec![0.1, 0.1],
                iterations: 1,
                logdet: 0.0,
                datafit: 0.0,
            }
        });
        assert!(trainer.diverged, "NaN nmll must mark the run diverged");
        assert_eq!(calls, 3, "must stop at the first non-finite evaluation");
        assert_eq!(trainer.history.len(), 3);
        assert!(params.iter().all(|v| v.is_finite()), "params stay finite");
        assert!(best.is_finite());
    }

    #[test]
    fn early_stopping_respects_patience() {
        // constant objective: should stop after patience steps
        let mut trainer = Trainer::new(TrainConfig {
            iters: 100,
            lr: 0.1,
            tol: 1e-12,
            patience: 5,
            verbose: false,
        });
        let mut params = vec![0.0];
        trainer.run(&mut params, |_| MllGrad {
            nmll: 1.0,
            grad: vec![0.0],
            iterations: 0,
            logdet: 0.0,
            datafit: 0.0,
        });
        assert!(trainer.history.len() <= 7);
    }
}
