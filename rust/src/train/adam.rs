//! Adam optimiser (Kingma & Ba) on raw (log-space) hyperparameters — the
//! optimiser used by every experiment in the paper (§6: "All methods use the
//! same optimizer (Adam) with identical hyperparameters").

/// Adam state.
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl Adam {
    pub fn new(n_params: usize, lr: f64) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; n_params],
            v: vec![0.0; n_params],
            t: 0,
        }
    }

    /// [`Adam::step`] with a non-finite guard: a NaN/∞ gradient leaves the
    /// optimiser state **and** the parameters untouched (a poisoned moment
    /// vector would corrupt every later step) and returns `false` so the
    /// caller can mark the trajectory diverged.
    pub fn step_guarded(&mut self, params: &mut [f64], grad: &[f64]) -> bool {
        if grad.iter().any(|g| !g.is_finite()) {
            return false;
        }
        self.step(params, grad);
        true
    }

    /// One update: params ← params − lr·m̂/(√v̂ + ε).
    pub fn step(&mut self, params: &mut [f64], grad: &[f64]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grad.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grad[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }

    pub fn reset(&mut self) {
        self.m.iter_mut().for_each(|v| *v = 0.0);
        self.v.iter_mut().for_each(|v| *v = 0.0);
        self.t = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimises_quadratic() {
        // f(x) = (x₀−3)² + 2(x₁+1)²
        let mut x = vec![0.0, 0.0];
        let mut opt = Adam::new(2, 0.1);
        for _ in 0..500 {
            let g = vec![2.0 * (x[0] - 3.0), 4.0 * (x[1] + 1.0)];
            opt.step(&mut x, &g);
        }
        assert!((x[0] - 3.0).abs() < 1e-2, "x0={}", x[0]);
        assert!((x[1] + 1.0).abs() < 1e-2, "x1={}", x[1]);
    }

    #[test]
    fn first_step_magnitude_is_lr() {
        // Adam's first step has magnitude ≈ lr regardless of gradient scale
        let mut x = vec![0.0];
        let mut opt = Adam::new(1, 0.05);
        opt.step(&mut x, &[1234.5]);
        assert!((x[0].abs() - 0.05).abs() < 1e-6);
    }

    #[test]
    fn reset_clears_state() {
        let mut opt = Adam::new(1, 0.1);
        let mut x = vec![0.0];
        opt.step(&mut x, &[1.0]);
        opt.reset();
        assert_eq!(opt.t, 0);
        let mut y = vec![0.0];
        opt.step(&mut y, &[1.0]);
        assert!((y[0] + 0.1).abs() < 1e-9);
    }

    #[test]
    fn guarded_step_rejects_non_finite_gradients() {
        let mut opt = Adam::new(2, 0.1);
        let mut x = vec![1.0, 2.0];
        assert!(opt.step_guarded(&mut x, &[0.5, -0.5]));
        let after_good = x.clone();
        let t_after_good = opt.t;
        // NaN and ∞ gradients must be no-ops on params AND optimizer state
        assert!(!opt.step_guarded(&mut x, &[f64::NAN, 0.0]));
        assert!(!opt.step_guarded(&mut x, &[0.0, f64::INFINITY]));
        assert_eq!(x, after_good);
        assert_eq!(opt.t, t_after_good);
        // and the optimiser still works afterwards
        assert!(opt.step_guarded(&mut x, &[0.5, -0.5]));
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn noisy_gradients_still_converge() {
        // BBMM gradients are stochastic — Adam must tolerate that
        let mut rng = crate::util::Rng::new(1);
        let mut x = vec![5.0];
        let mut opt = Adam::new(1, 0.05);
        for _ in 0..2000 {
            let g = 2.0 * (x[0] - 1.0) + 0.5 * rng.normal();
            opt.step(&mut x, &[g]);
        }
        assert!((x[0] - 1.0).abs() < 0.2, "x={}", x[0]);
    }
}
