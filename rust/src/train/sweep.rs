//! **Batched hyperparameter sweeps**: b Adam trajectories stepped in
//! lockstep, one batched MLL + gradient evaluation per iteration — the
//! training-side payoff of the batch axis (one
//! [`crate::gp::mll::BatchInferenceEngine`] call per step instead of b
//! scalar engine calls).
//!
//! [`SweepTrainer`] owns the optimisation mechanics only; the model glue
//! (`ExactGp::fit_sweep`, `SgprModel::fit_sweep`) owns the operators and
//! supplies a *batched objective* closure that lifts the active
//! candidates' parameters into a [`crate::linalg::op::BatchOp`] and
//! evaluates them together. **Per-candidate early stopping** mirrors
//! `mbcg_batch`'s frozen systems: a candidate that converges (patience on
//! its own nmll) or diverges (non-finite nmll/gradient) drops out of the
//! active set, so later iterations batch only the still-improving
//! candidates — the batched product shrinks exactly like the solver's.

use crate::gp::mll::MllGrad;
use crate::train::adam::Adam;
use crate::train::trainer::{TrainConfig, TrainRecord};
use crate::util::{Rng, Timer};

/// Lifecycle of one sweep candidate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CandidateStatus {
    /// still evaluated and stepped each iteration
    Active,
    /// early-stopped: nmll stopped improving for `patience` steps
    Converged,
    /// failed fast on a non-finite nmll/gradient; params keep their last
    /// finite value and the candidate never re-enters the batch
    Diverged,
}

/// One restart's trajectory through the sweep.
pub struct Candidate {
    /// current raw (log-space) parameters (end of run: one Adam step past
    /// the last evaluation — see [`Candidate::best_params`])
    pub params: Vec<f64>,
    /// the parameters that *achieved* [`Candidate::best_nmll`] (snapshot
    /// taken at evaluation time, before that iteration's Adam step) — what
    /// a winner materialises from
    pub best_params: Vec<f64>,
    /// lifecycle state (drives batch membership)
    pub status: CandidateStatus,
    /// best (lowest) finite nmll observed
    pub best_nmll: f64,
    /// per-iteration training records (same schema as [`TrainRecord`])
    pub history: Vec<TrainRecord>,
    adam: Adam,
    since_best: usize,
}

impl Candidate {
    fn new(params: Vec<f64>, lr: f64) -> Self {
        let adam = Adam::new(params.len(), lr);
        Candidate {
            best_params: params.clone(),
            params,
            status: CandidateStatus::Active,
            best_nmll: f64::INFINITY,
            history: Vec::new(),
            adam,
            since_best: 0,
        }
    }
}

/// Steps b Adam states in lockstep against a batched objective; see the
/// module docs for the candidate lifecycle.
pub struct SweepTrainer {
    /// shared optimisation knobs (`tol`/`patience` apply per candidate)
    pub config: TrainConfig,
    /// the b candidate trajectories
    pub candidates: Vec<Candidate>,
}

impl SweepTrainer {
    /// One candidate per initial raw-parameter vector (all the same
    /// length); every candidate gets its own Adam state at `config.lr`.
    pub fn new(config: TrainConfig, inits: Vec<Vec<f64>>) -> Self {
        assert!(!inits.is_empty(), "SweepTrainer: empty candidate set");
        let d = inits[0].len();
        for p in &inits {
            assert_eq!(p.len(), d, "SweepTrainer: candidate length mismatch");
        }
        let lr = config.lr;
        SweepTrainer {
            config,
            candidates: inits.into_iter().map(|p| Candidate::new(p, lr)).collect(),
        }
    }

    /// Run up to `config.iters` lockstep iterations. Each iteration,
    /// `objective` receives the **active** candidates as `(index, params)`
    /// pairs — the model glue batches exactly these — and must return one
    /// [`MllGrad`] per entry, in order. Returns the winning candidate
    /// index ([`SweepTrainer::best`]).
    pub fn run(
        &mut self,
        mut objective: impl FnMut(&[(usize, Vec<f64>)]) -> Vec<MllGrad>,
    ) -> Option<usize> {
        let timer = Timer::start();
        for it in 0..self.config.iters {
            let active: Vec<(usize, Vec<f64>)> = self
                .candidates
                .iter()
                .enumerate()
                .filter(|(_, c)| c.status == CandidateStatus::Active)
                .map(|(i, c)| (i, c.params.clone()))
                .collect();
            if active.is_empty() {
                break;
            }
            let results = objective(&active);
            assert_eq!(
                results.len(),
                active.len(),
                "sweep objective must return one MllGrad per active candidate"
            );
            for ((idx, _), res) in active.iter().zip(results) {
                let cand = &mut self.candidates[*idx];
                let gnorm = res.grad.iter().map(|g| g * g).sum::<f64>().sqrt();
                cand.history.push(TrainRecord {
                    iter: it,
                    nmll: res.nmll,
                    grad_norm: gnorm,
                    elapsed_s: timer.elapsed_s(),
                    cg_iterations: res.iterations,
                });
                if self.config.verbose {
                    eprintln!(
                        "[sweep] iter {it:4} cand {idx:3} nmll {:.6} |g| {:.3e}",
                        res.nmll, gnorm
                    );
                }
                // fail fast: a diverged candidate is dropped from the batch
                // instead of poisoning its optimiser (or wasting b-th of
                // every later batched product on NaNs)
                if !res.nmll.is_finite() || !gnorm.is_finite() {
                    cand.status = CandidateStatus::Diverged;
                    continue;
                }
                if res.nmll < cand.best_nmll - self.config.tol {
                    cand.best_nmll = res.nmll;
                    // snapshot the params this evaluation was taken at
                    // (cand.params has not been stepped yet this iteration)
                    cand.best_params.copy_from_slice(&cand.params);
                    cand.since_best = 0;
                } else {
                    if res.nmll < cand.best_nmll {
                        cand.best_nmll = res.nmll;
                        cand.best_params.copy_from_slice(&cand.params);
                    }
                    cand.since_best += 1;
                    if self.config.tol > 0.0 && cand.since_best >= self.config.patience {
                        cand.status = CandidateStatus::Converged;
                        continue;
                    }
                }
                if !cand.adam.step_guarded(&mut cand.params, &res.grad) {
                    cand.status = CandidateStatus::Diverged;
                }
            }
        }
        self.best()
    }

    /// The winning candidate: lowest `best_nmll` among candidates that
    /// never diverged (`None` when every candidate diverged).
    pub fn best(&self) -> Option<usize> {
        self.candidates
            .iter()
            .enumerate()
            .filter(|(_, c)| c.status != CandidateStatus::Diverged && c.best_nmll.is_finite())
            .min_by(|(_, a), (_, b)| a.best_nmll.total_cmp(&b.best_nmll))
            .map(|(i, _)| i)
    }

    /// Indices of candidates still active.
    pub fn active(&self) -> Vec<usize> {
        self.candidates
            .iter()
            .enumerate()
            .filter(|(_, c)| c.status == CandidateStatus::Active)
            .map(|(i, _)| i)
            .collect()
    }

    /// Consume the trainer into a [`SweepReport`].
    pub fn into_report(self) -> SweepReport {
        let best = self.best();
        SweepReport {
            best,
            candidates: self.candidates,
        }
    }
}

/// The outcome of a batched sweep: every candidate's final trajectory plus
/// the winner.
pub struct SweepReport {
    /// winning candidate index (lowest best nmll among non-diverged), or
    /// `None` when every candidate diverged
    pub best: Option<usize>,
    /// per-candidate trajectories, in init order
    pub candidates: Vec<Candidate>,
}

impl SweepReport {
    /// The winner's raw parameters **at its best evaluation** (not its
    /// end-of-run parameters, which sit one Adam step past the last
    /// evaluation and can be worse under stochastic gradients).
    pub fn best_params(&self) -> Option<&[f64]> {
        self.best.map(|i| self.candidates[i].best_params.as_slice())
    }

    /// The winner's best nmll.
    pub fn best_nmll(&self) -> Option<f64> {
        self.best.map(|i| self.candidates[i].best_nmll)
    }

    /// One human-readable line per candidate (CLI/report output).
    pub fn summary_lines(&self) -> Vec<String> {
        self.candidates
            .iter()
            .enumerate()
            .map(|(i, c)| {
                let mark = if Some(i) == self.best { " <- best" } else { "" };
                format!(
                    "candidate {i:3}: nmll {:>12.4} after {:3} iters [{:?}]{mark}",
                    c.best_nmll,
                    c.history.len(),
                    c.status
                )
            })
            .collect()
    }
}

/// Multi-restart initial candidates: candidate 0 is the template itself;
/// the rest perturb every raw (log-space) parameter by `N(0, spread²)` —
/// the standard random-restart initialisation for non-convex mll surfaces.
pub fn multi_restart_inits(
    template: &[f64],
    restarts: usize,
    spread: f64,
    seed: u64,
) -> Vec<Vec<f64>> {
    assert!(restarts > 0, "need at least one restart");
    let mut rng = Rng::new(seed);
    (0..restarts)
        .map(|r| {
            if r == 0 {
                template.to_vec()
            } else {
                template.iter().map(|v| v + spread * rng.normal()).collect()
            }
        })
        .collect()
}

/// A **shared-covariance** sweep initialisation: every candidate keeps the
/// template's kernel parameters and takes one σ² from the grid — the
/// configuration where the batched engine's fused `K·[D₁ … D_b]` fast
/// path engages (the covariance is literally shared).
pub fn noise_grid_inits(template: &[f64], noises: &[f64]) -> Vec<Vec<f64>> {
    assert!(!noises.is_empty(), "need at least one noise level");
    assert!(
        noises.iter().all(|&s| s > 0.0),
        "noise levels must be positive"
    );
    let last = template.len() - 1;
    noises
        .iter()
        .map(|&s2| {
            let mut p = template.to_vec();
            p[last] = s2.ln();
            p
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad_for(params: &[f64]) -> MllGrad {
        // quadratic bowl: nmll = Σ (p − 1)², grad = 2(p − 1)
        let nmll: f64 = params.iter().map(|p| (p - 1.0) * (p - 1.0)).sum();
        MllGrad {
            nmll,
            grad: params.iter().map(|p| 2.0 * (p - 1.0)).collect(),
            iterations: 1,
            logdet: 0.0,
            datafit: 0.0,
        }
    }

    #[test]
    fn lockstep_sweep_minimises_all_candidates_and_picks_the_best() {
        let inits = vec![vec![3.0, -2.0], vec![1.2, 0.9], vec![-4.0, 4.0]];
        let mut trainer = SweepTrainer::new(
            TrainConfig {
                iters: 300,
                lr: 0.05,
                ..Default::default()
            },
            inits,
        );
        let best = trainer.run(|active| active.iter().map(|(_, p)| grad_for(p)).collect());
        // candidate 1 starts closest to the optimum and must win
        assert_eq!(best, Some(1));
        for c in &trainer.candidates {
            assert!(c.best_nmll < 0.1, "nmll {}", c.best_nmll);
        }
    }

    #[test]
    fn converged_and_diverged_candidates_drop_out_of_the_batch() {
        let inits = vec![vec![0.0], vec![0.0], vec![0.0]];
        let mut trainer = SweepTrainer::new(
            TrainConfig {
                iters: 40,
                lr: 0.1,
                tol: 1e-9,
                patience: 3,
                verbose: false,
            },
            inits,
        );
        let mut active_sizes = Vec::new();
        let best = trainer.run(|active| {
            active_sizes.push(active.len());
            let step = active_sizes.len();
            active
                .iter()
                .map(|(idx, p)| match idx {
                    // candidate 0: constant objective — converges by patience
                    0 => MllGrad {
                        nmll: 5.0,
                        grad: vec![0.0],
                        iterations: 1,
                        logdet: 0.0,
                        datafit: 0.0,
                    },
                    // candidate 1: goes NaN at step 2 — diverges, fail fast
                    1 if step >= 2 => MllGrad {
                        nmll: f64::NAN,
                        grad: vec![0.0],
                        iterations: 1,
                        logdet: 0.0,
                        datafit: 0.0,
                    },
                    // candidate 2: strictly improving forever — stays
                    // active through every iteration and wins the sweep
                    _ => MllGrad {
                        nmll: 4.0 - step as f64,
                        grad: vec![0.1 + 0.0 * p[0]],
                        iterations: 1,
                        logdet: 0.0,
                        datafit: 0.0,
                    },
                })
                .collect()
        });
        assert_eq!(trainer.candidates[0].status, CandidateStatus::Converged);
        assert_eq!(trainer.candidates[1].status, CandidateStatus::Diverged);
        assert_eq!(trainer.candidates[2].status, CandidateStatus::Active);
        // the batch shrank: 3 → (after cand 1 dies at step 2, cand 0 at
        // patience) → eventually only candidate 2 remains
        assert_eq!(active_sizes[0], 3);
        assert_eq!(*active_sizes.last().unwrap(), 1);
        // candidate 1's params stayed finite (divergence froze them)
        assert!(trainer.candidates[1].params[0].is_finite());
        // winner must be the healthy candidate 2
        assert_eq!(best, Some(2));
        // diverged candidate never re-entered: history stops at step 2
        assert_eq!(trainer.candidates[1].history.len(), 2);
    }

    #[test]
    fn best_params_snapshot_the_best_evaluation_not_the_last_step() {
        // nmll dips at step 3 then worsens; the report must hand back the
        // parameters the dip was evaluated at, not the wandered-off final
        // ones (stochastic gradients make this the common case)
        let mut trainer = SweepTrainer::new(
            TrainConfig {
                iters: 6,
                lr: 0.5,
                ..Default::default()
            },
            vec![vec![0.0]],
        );
        let nmlls = [10.0, 8.0, 3.0, 9.0, 11.0, 12.0];
        let mut step = 0usize;
        let mut params_at_best = f64::NAN;
        let best = trainer.run(|active| {
            let p = active[0].1[0];
            if step == 2 {
                params_at_best = p;
            }
            let nmll = nmlls[step];
            step += 1;
            vec![MllGrad {
                nmll,
                grad: vec![1.0],
                iterations: 1,
                logdet: 0.0,
                datafit: 0.0,
            }]
        });
        assert_eq!(best, Some(0));
        let report = trainer.into_report();
        assert_eq!(report.best_nmll(), Some(3.0));
        let got = report.best_params().unwrap()[0];
        assert_eq!(got, params_at_best, "winner params must match the best evaluation");
        // and the end-of-run params differ (five more Adam steps happened)
        assert_ne!(report.candidates[0].params[0], got);
    }

    #[test]
    fn all_diverged_yields_no_winner() {
        let mut trainer = SweepTrainer::new(
            TrainConfig {
                iters: 5,
                lr: 0.1,
                ..Default::default()
            },
            vec![vec![0.0]],
        );
        let best = trainer.run(|active| {
            active
                .iter()
                .map(|_| MllGrad {
                    nmll: f64::INFINITY,
                    grad: vec![f64::NAN],
                    iterations: 0,
                    logdet: 0.0,
                    datafit: 0.0,
                })
                .collect()
        });
        assert_eq!(best, None);
        let report = trainer.into_report();
        assert_eq!(report.best, None);
        assert!(report.best_params().is_none());
        assert_eq!(report.summary_lines().len(), 1);
    }

    #[test]
    fn init_helpers_shape_the_candidate_set() {
        let template = vec![0.5, -0.5, (0.1f64).ln()];
        let inits = multi_restart_inits(&template, 4, 0.3, 7);
        assert_eq!(inits.len(), 4);
        assert_eq!(inits[0], template, "candidate 0 is the template");
        for c in &inits[1..] {
            assert_eq!(c.len(), 3);
            assert!(c.iter().zip(&template).any(|(a, b)| a != b));
        }
        let grid = noise_grid_inits(&template, &[0.05, 0.2]);
        assert_eq!(grid.len(), 2);
        for (g, &s2) in grid.iter().zip(&[0.05, 0.2]) {
            assert_eq!(&g[..2], &template[..2], "kernel params shared");
            assert!((g[2] - (s2 as f64).ln()).abs() < 1e-15);
        }
    }
}
