//! Hyperparameter optimisation: Adam (the paper's optimiser, §6), the
//! scalar training loop driving any [`crate::gp::InferenceEngine`], and
//! the batched [`SweepTrainer`] stepping a whole hyperparameter sweep in
//! lockstep through one [`crate::gp::mll::BatchInferenceEngine`] call per
//! iteration.

pub mod adam;
pub mod sweep;
pub mod trainer;

pub use adam::Adam;
pub use sweep::{
    multi_restart_inits, noise_grid_inits, Candidate, CandidateStatus, SweepReport, SweepTrainer,
};
pub use trainer::{TrainConfig, TrainRecord, Trainer};
