//! Hyperparameter optimisation: Adam (the paper's optimiser, §6) plus the
//! training loop driving any [`crate::gp::InferenceEngine`].

pub mod adam;
pub mod trainer;

pub use adam::Adam;
pub use trainer::{TrainConfig, TrainRecord, Trainer};
