//! # bbmm-gp — Blackbox Matrix-Matrix Gaussian Process inference
//!
//! A Rust + JAX/Pallas reproduction of *GPyTorch: Blackbox Matrix-Matrix
//! Gaussian Process Inference with GPU Acceleration* (Gardner, Pleiss,
//! Bindel, Weinberger, Wilson — NeurIPS 2018).
//!
//! The crate is organised as the paper's system is:
//!
//! - [`tensor`] / [`util`] — dense-matrix and RNG substrates.
//! - [`linalg`] — the numerical core: dense Cholesky (baseline), standard
//!   PCG, the paper's **mBCG** (batched CG with Lanczos-tridiagonal
//!   recovery), Lanczos itself (Dong et al. baseline), the rank-k **pivoted
//!   Cholesky** preconditioner, stochastic trace estimation, FFT and
//!   Toeplitz operators — and [`linalg::op`], the composable **`LinearOp`
//!   operator algebra** plus its solve-strategy dispatcher.
//! - [`kernels`] — covariance functions (RBF / Matérn / linear /
//!   compositions / deep-kernel features) and the kernel-side operators of
//!   the algebra; every model is a thin composition whose only hot method
//!   is `matmul` (`K̂·M`) with analytic `dK̂/dθ·M`. (The seed-era
//!   `kernels::KernelOperator` re-export of `LinearOp` has been removed.)
//! - [`gp`] — GP models and inference engines: exact GP with BBMM and
//!   Cholesky engines, SGPR (SoR), SKI (KISS-GP), and the Dong et al.
//!   sequential-Lanczos engine used as the SKI baseline; the batched
//!   [`gp::mll::BatchBbmmEngine`] evaluates a whole hyperparameter sweep
//!   through one `mbcg_batch` call per step.
//! - [`train`] — Adam on raw hyperparameters, the scalar training loop,
//!   and the lockstep multi-restart [`train::SweepTrainer`].
//! - [`data`] — synthetic UCI-equivalent datasets and a CSV loader.
//! - [`runtime`] — PJRT artifact loading/execution (the L2/L1 AOT bridge).
//! - [`coordinator`] — prediction server: request router + dynamic batcher.
//! - [`bench`] — the in-tree benchmark harness (offline criterion stand-in).

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod gp;
pub mod kernels;
pub mod linalg;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;
