//! Small substrates: RNG, parallel-for, timers, CLI argument parsing.
//!
//! The offline build environment only provides the `xla` + `anyhow` crates,
//! so the usual ecosystem pieces (rand, rayon, clap) are implemented here,
//! scoped to exactly what the BBMM stack needs.

pub mod alloc;
pub mod cli;
pub mod fastmath;
pub mod par;
pub mod rng;
pub mod scratch;
pub mod timer;

pub use par::parallel_for;
pub use rng::Rng;
pub use timer::Timer;
