//! xoshiro256++ pseudo-random number generator plus the distributions the
//! BBMM stack needs: uniform, standard normal (Box–Muller), and Rademacher
//! probe vectors (paper §6 uses Rademacher probes for the stochastic trace
//! and log-determinant estimators).

/// xoshiro256++ PRNG (Blackman & Vigna). Deterministic, seedable, fast.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    spare: Option<f64>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    (x << k) | (x >> (64 - k))
}

/// splitmix64, used to expand a single seed into the xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style bounded rejection is overkill for our uses; modulo
        // bias is < 2^-40 for n < 2^24.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (caches the second variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Rademacher variate: ±1 with equal probability.
    #[inline]
    pub fn rademacher(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Fill a slice with Rademacher ±1.
    pub fn fill_rademacher(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.rademacher();
        }
    }

    /// A vector of n standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        let mut v = vec![0.0; n];
        self.fill_normal(&mut v);
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Split off an independent generator (for deterministic parallel use).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(123);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn rademacher_is_pm_one_and_balanced() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.rademacher();
            assert!(v == 1.0 || v == -1.0);
            sum += v;
        }
        assert!((sum / n as f64).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
