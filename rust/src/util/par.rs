//! Minimal data-parallelism substrate (rayon is unavailable offline).
//!
//! `parallel_for` splits an index range across `std::thread::scope` workers.
//! Thread spawn costs ~20µs, so callers gate on problem size (the helpers
//! here do that automatically via `GRAIN`).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (cached; override with BBMM_THREADS).
pub fn num_threads() -> usize {
    static N: AtomicUsize = AtomicUsize::new(0);
    let cached = N.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = std::env::var("BBMM_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        });
    N.store(n, Ordering::Relaxed);
    n
}

/// Minimum amount of per-thread work (in "items") below which we stay serial.
const GRAIN: usize = 4;

/// Run `body(i)` for every `i in 0..n`, splitting the range across threads.
///
/// `body` must be `Sync` (called concurrently from several threads). Each
/// index is visited exactly once.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, body: F) {
    let nt = num_threads().min(n.div_ceil(GRAIN)).max(1);
    if nt <= 1 || n == 0 {
        for i in 0..n {
            body(i);
        }
        return;
    }
    let chunk = n.div_ceil(nt);
    std::thread::scope(|s| {
        for t in 0..nt {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let body = &body;
            s.spawn(move || {
                for i in lo..hi {
                    body(i);
                }
            });
        }
    });
}

/// Run `body(chunk_index, lo, hi)` over ~equal contiguous chunks of `0..n`.
/// Useful when the body wants to amortise per-chunk setup.
pub fn parallel_chunks<F: Fn(usize, usize, usize) + Sync>(n: usize, min_chunk: usize, body: F) {
    let nt = if min_chunk == 0 {
        num_threads()
    } else {
        num_threads().min(n.div_ceil(min_chunk)).max(1)
    };
    if nt <= 1 || n == 0 {
        body(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(nt);
    std::thread::scope(|s| {
        for t in 0..nt {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let body = &body;
            s.spawn(move || body(t, lo, hi));
        }
    });
}

/// Map over mutable row-chunks of a flat buffer: splits `buf` (logically
/// `rows × row_len`) into contiguous row ranges, one per thread, and calls
/// `body(row_lo, rows_chunk)` with the mutable sub-slice for those rows.
pub fn parallel_rows_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    buf: &mut [T],
    rows: usize,
    row_len: usize,
    body: F,
) {
    assert_eq!(buf.len(), rows * row_len, "buffer/rows mismatch");
    let nt = num_threads().min(rows.div_ceil(GRAIN)).max(1);
    if nt <= 1 || rows == 0 {
        body(0, buf);
        return;
    }
    let chunk = rows.div_ceil(nt);
    std::thread::scope(|s| {
        let mut rest = buf;
        let mut row_lo = 0usize;
        while row_lo < rows {
            let take = chunk.min(rows - row_lo);
            let (head, tail) = rest.split_at_mut(take * row_len);
            rest = tail;
            let body = &body;
            let lo = row_lo;
            s.spawn(move || body(lo, head));
            row_lo += take;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn parallel_for_visits_every_index_once() {
        let n = 1000;
        let counts: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(n, |i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        for c in &counts {
            assert_eq!(c.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn parallel_for_empty() {
        parallel_for(0, |_| panic!("should not be called"));
    }

    #[test]
    fn parallel_rows_mut_covers_buffer() {
        let rows = 37;
        let row_len = 5;
        let mut buf = vec![0.0f64; rows * row_len];
        parallel_rows_mut(&mut buf, rows, row_len, |row_lo, chunk| {
            for (r, row) in chunk.chunks_mut(row_len).enumerate() {
                for v in row.iter_mut() {
                    *v = (row_lo + r) as f64;
                }
            }
        });
        for r in 0..rows {
            for c in 0..row_len {
                assert_eq!(buf[r * row_len + c], r as f64);
            }
        }
    }

    #[test]
    fn parallel_chunks_partition() {
        let n = 100;
        let seen: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks(n, 1, |_t, lo, hi| {
            for i in lo..hi {
                seen[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        for s in &seen {
            assert_eq!(s.load(Ordering::Relaxed), 1);
        }
    }
}
