//! Minimal data-parallelism substrate (rayon is unavailable offline),
//! backed by a **lazily-started persistent worker pool**.
//!
//! The seed implementation spawned `std::thread::scope` workers on every
//! parallel region (~20µs per spawn), which a 50-iteration mBCG solve pays
//! hundreds of times. The pool here is started once — `num_threads() − 1`
//! channel-fed workers (`BBMM_THREADS`-sized, see [`set_threads`]) parked
//! on a condvar — and every region after that is a lock-push plus a wake.
//!
//! Regions are **allocation-free**: the batch descriptor lives on the
//! submitting thread's stack, workers claim chunk indices with an atomic
//! counter, and the submitter both participates in its own batch and
//! blocks until every claimed chunk has finished (so stack borrows stay
//! valid — the same guarantee `thread::scope` gave, enforced here with a
//! completion count plus a worker reference count). Nested regions are
//! safe: a submitter inside a worker drains its own batch itself if no
//! peer is free, so progress never depends on pool capacity.

use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Number of worker threads to use (cached; `BBMM_THREADS` overrides the
/// detected parallelism, [`set_threads`] overrides both).
pub fn num_threads() -> usize {
    let cached = THREADS.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = std::env::var("BBMM_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        });
    THREADS.store(n, Ordering::Relaxed);
    n
}

/// Override the worker count (the `--threads` CLI flag). Takes full effect
/// when called before the first parallel region — the pool spawns its
/// workers lazily at that point; afterwards it only changes the serial/
/// parallel gating, not the number of live workers.
pub fn set_threads(n: usize) {
    if n > 0 {
        THREADS.store(n, Ordering::Relaxed);
    }
}

/// Minimum amount of per-thread work (in "items") below which we stay serial.
const GRAIN: usize = 4;

/// One parallel region: `n` chunk tasks claimed by index. Lives on the
/// submitting thread's stack; the queue holds raw pointers to it, made
/// sound by the submit protocol (see [`submit_and_run`]).
struct Batch {
    /// the chunk body, lifetime-erased; valid until the submitter returns
    task: *const (dyn Fn(usize) + Sync),
    /// number of chunk tasks
    n: usize,
    /// next unclaimed chunk index
    next: AtomicUsize,
    /// chunks fully executed
    done: AtomicUsize,
    /// pool workers currently holding a reference (bumped under the queue
    /// lock, so a batch still in the queue is never freed mid-grab)
    refs: AtomicUsize,
    /// completion flag + wakeups for the submitter
    finished: Mutex<bool>,
    cv: Condvar,
    /// first panic payload from any chunk (re-thrown by the submitter)
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

// SAFETY: the raw task pointer is only dereferenced while the submitter
// is blocked in `submit_and_run` (claimed chunks keep `done < n`).
unsafe impl Send for Batch {}
unsafe impl Sync for Batch {}

struct Pool {
    queue: Mutex<VecDeque<*const Batch>>,
    ready: Condvar,
}

// SAFETY: the queued pointers are managed by the submit protocol above.
unsafe impl Send for Pool {}
unsafe impl Sync for Pool {}

fn pool() -> &'static Pool {
    static POOL: OnceLock<&'static Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let pool: &'static Pool = Box::leak(Box::new(Pool {
            queue: Mutex::new(VecDeque::with_capacity(64)),
            ready: Condvar::new(),
        }));
        let workers = num_threads().saturating_sub(1);
        for w in 0..workers {
            std::thread::Builder::new()
                .name(format!("bbmm-worker-{w}"))
                .spawn(move || worker_loop(pool))
                .expect("failed to spawn pool worker");
        }
        pool
    })
}

fn worker_loop(pool: &'static Pool) {
    loop {
        let batch: &Batch = {
            let mut q = pool.queue.lock().unwrap();
            loop {
                if let Some(&front) = q.front() {
                    // bump refs under the lock: the submitter cannot free
                    // the batch while it is queued, and cannot dequeue-and-
                    // return before observing our reference
                    unsafe {
                        (*front).refs.fetch_add(1, Ordering::AcqRel);
                        break &*front;
                    }
                }
                q = pool.ready.wait(q).unwrap();
            }
        };
        run_batch(pool, batch);
        // Release. This MUST be the worker's final touch of the batch: the
        // submitter spins on `refs` (it does not condvar-wait on it), so
        // the moment this RMW completes it may free the stack batch —
        // locking/notifying anything on it here would be use-after-free.
        batch.refs.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Claim and execute chunks of `batch` until none remain, then drop the
/// batch from the queue front (if it is still there).
fn run_batch(pool: &Pool, batch: &Batch) {
    loop {
        let i = batch.next.fetch_add(1, Ordering::Relaxed);
        if i >= batch.n {
            let mut q = pool.queue.lock().unwrap();
            if let Some(&front) = q.front() {
                if std::ptr::eq(front, batch as *const Batch) {
                    q.pop_front();
                }
            }
            return;
        }
        let task: &(dyn Fn(usize) + Sync) = unsafe { &*batch.task };
        if let Err(payload) = panic::catch_unwind(AssertUnwindSafe(|| task(i))) {
            let mut slot = batch.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        if batch.done.fetch_add(1, Ordering::AcqRel) + 1 == batch.n {
            let mut f = batch.finished.lock().unwrap();
            *f = true;
            batch.cv.notify_all();
        }
    }
}

/// Run `task(0..n)` across the pool. The submitting thread participates;
/// returns only after every chunk has executed and no worker still holds
/// the (stack-allocated) batch. Panics in chunks are re-thrown here.
fn submit_and_run(n: usize, task: &(dyn Fn(usize) + Sync)) {
    if n == 0 {
        return;
    }
    if n == 1 || num_threads() <= 1 {
        for i in 0..n {
            task(i);
        }
        return;
    }
    let pool = pool();
    let batch = Batch {
        task: task as *const (dyn Fn(usize) + Sync),
        n,
        next: AtomicUsize::new(0),
        done: AtomicUsize::new(0),
        refs: AtomicUsize::new(0),
        finished: Mutex::new(false),
        cv: Condvar::new(),
        panic: Mutex::new(None),
    };
    {
        let mut q = pool.queue.lock().unwrap();
        q.push_back(&batch as *const Batch);
        pool.ready.notify_all();
    }
    // participate: the submitter drains its own batch (alone, if every
    // worker is busy — this is what makes nested regions deadlock-free)
    run_batch(pool, &batch);
    // wait for chunks claimed by pool workers
    {
        let mut f = batch.finished.lock().unwrap();
        while !*f {
            f = batch.cv.wait(f).unwrap();
        }
    }
    // unqueue (no new grabs), then wait for grabbed references to drain so
    // the stack batch cannot be touched after we return
    {
        let mut q = pool.queue.lock().unwrap();
        if let Some(pos) = q.iter().position(|&p| std::ptr::eq(p, &batch as *const Batch)) {
            q.remove(pos);
        }
    }
    // Spin-drain rather than condvar-wait: a worker's release is a single
    // atomic decrement with no lock/notify after it, so observing refs == 0
    // (Acquire) happens-after the worker's LAST access to the batch and it
    // is then safe to free. The window is tiny — every chunk has already
    // completed (`finished` above), so lingering references are workers
    // between their last chunk and the decrement.
    while batch.refs.load(Ordering::Acquire) != 0 {
        std::thread::yield_now();
    }
    if let Some(payload) = batch.panic.lock().unwrap().take() {
        panic::resume_unwind(payload);
    }
}

/// Run `body(i)` for every `i in 0..n`, splitting the range across the
/// pool. `body` must be `Sync` (called concurrently from several threads).
/// Each index is visited exactly once.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, body: F) {
    let nt = num_threads().min(n.div_ceil(GRAIN)).max(1);
    if nt <= 1 || n == 0 {
        for i in 0..n {
            body(i);
        }
        return;
    }
    let chunk = n.div_ceil(nt);
    let n_chunks = n.div_ceil(chunk);
    submit_and_run(n_chunks, &|t| {
        let lo = t * chunk;
        let hi = ((t + 1) * chunk).min(n);
        for i in lo..hi {
            body(i);
        }
    });
}

/// Run `body(chunk_index, lo, hi)` over ~equal contiguous chunks of `0..n`.
/// Useful when the body wants to amortise per-chunk setup.
pub fn parallel_chunks<F: Fn(usize, usize, usize) + Sync>(n: usize, min_chunk: usize, body: F) {
    let nt = if min_chunk == 0 {
        num_threads()
    } else {
        num_threads().min(n.div_ceil(min_chunk)).max(1)
    };
    if nt <= 1 || n == 0 {
        body(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(nt);
    let n_chunks = n.div_ceil(chunk);
    submit_and_run(n_chunks, &|t| {
        let lo = t * chunk;
        let hi = ((t + 1) * chunk).min(n);
        if lo < hi {
            body(t, lo, hi);
        }
    });
}

/// Shareable base pointer for the disjoint-rows driver below.
struct SendPtr<T>(*mut T);
// SAFETY: each chunk task touches a disjoint row range of the buffer.
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Map over mutable row-chunks of a flat buffer: splits `buf` (logically
/// `rows × row_len`) into contiguous row ranges, one per chunk task, and
/// calls `body(row_lo, rows_chunk)` with the mutable sub-slice for those
/// rows.
pub fn parallel_rows_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    buf: &mut [T],
    rows: usize,
    row_len: usize,
    body: F,
) {
    assert_eq!(buf.len(), rows * row_len, "buffer/rows mismatch");
    let nt = num_threads().min(rows.div_ceil(GRAIN)).max(1);
    if nt <= 1 || rows == 0 {
        body(0, buf);
        return;
    }
    let chunk = rows.div_ceil(nt);
    let n_chunks = rows.div_ceil(chunk);
    let base = SendPtr(buf.as_mut_ptr());
    submit_and_run(n_chunks, &|t| {
        let lo = t * chunk;
        let hi = ((t + 1) * chunk).min(rows);
        if lo >= hi {
            return;
        }
        // SAFETY: chunk tasks own disjoint row ranges of the buffer, and
        // the submitter blocks until every task completes.
        let slice = unsafe {
            std::slice::from_raw_parts_mut(base.0.add(lo * row_len), (hi - lo) * row_len)
        };
        body(lo, slice);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn parallel_for_visits_every_index_once() {
        let n = 1000;
        let counts: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(n, |i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        for c in &counts {
            assert_eq!(c.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn parallel_for_empty() {
        parallel_for(0, |_| panic!("should not be called"));
    }

    #[test]
    fn parallel_rows_mut_covers_buffer() {
        let rows = 37;
        let row_len = 5;
        let mut buf = vec![0.0f64; rows * row_len];
        parallel_rows_mut(&mut buf, rows, row_len, |row_lo, chunk| {
            for (r, row) in chunk.chunks_mut(row_len).enumerate() {
                for v in row.iter_mut() {
                    *v = (row_lo + r) as f64;
                }
            }
        });
        for r in 0..rows {
            for c in 0..row_len {
                assert_eq!(buf[r * row_len + c], r as f64);
            }
        }
    }

    #[test]
    fn parallel_chunks_partition() {
        let n = 100;
        let seen: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks(n, 1, |_t, lo, hi| {
            for i in lo..hi {
                seen[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        for s in &seen {
            assert_eq!(s.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn pool_survives_many_back_to_back_regions() {
        // the persistent pool must stay healthy across thousands of tiny
        // regions (the per-iteration cadence of an mBCG solve)
        let total = AtomicU64::new(0);
        for _ in 0..2000 {
            parallel_for(64, |i| {
                total.fetch_add(i as u64, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 2000 * (64 * 63 / 2));
    }

    #[test]
    fn nested_regions_complete() {
        let hits: Vec<AtomicU64> = (0..16 * 16).map(|_| AtomicU64::new(0)).collect();
        parallel_for(16, |outer| {
            parallel_for(16, |inner| {
                hits[outer * 16 + inner].fetch_add(1, Ordering::Relaxed);
            });
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn worker_panic_propagates_to_the_submitter() {
        let result = std::panic::catch_unwind(|| {
            parallel_for(64, |i| {
                if i == 13 {
                    panic!("boom from chunk");
                }
            });
        });
        assert!(result.is_err(), "a chunk panic must reach the caller");
        // and the pool still works afterwards
        let total = AtomicU64::new(0);
        parallel_for(100, |i| {
            total.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 100 * 99 / 2);
    }
}
