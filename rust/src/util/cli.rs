//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Malformed option values surface as [`CliError`]s so entry points can
//! print a usage message and exit non-zero instead of aborting mid-serve.

use std::collections::BTreeMap;
use std::fmt;

/// A malformed command-line option value.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CliError {
    /// the offending flag name (without the leading `--`)
    pub flag: String,
    /// what went wrong
    pub message: String,
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "--{}: {}", self.flag, self.message)
    }
}

impl std::error::Error for CliError {}

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// positional arguments in order
    pub positional: Vec<String>,
    /// --key value / --key=value pairs (last occurrence wins)
    pub options: BTreeMap<String, String>,
    /// every occurrence of each --key, in order (repeatable options like
    /// `--tenant a=exact --tenant b=sgpr`)
    pub multi: BTreeMap<String, Vec<String>>,
    /// bare --flags
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                    args.multi.entry(k.to_string()).or_default().push(v.to_string());
                } else if iter
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(stripped.to_string(), v.clone());
                    args.multi.entry(stripped.to_string()).or_default().push(v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Every occurrence of a repeatable option, in command-line order
    /// (empty when the option never appeared).
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.multi
            .get(name)
            .map(|vs| vs.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    /// A copy of these arguments with the given options overridden — the
    /// per-tenant launcher path (`--tenant name=model@dataset` expands to
    /// the canonical single-model argument set).
    pub fn with_overrides(&self, overrides: &[(&str, &str)]) -> Args {
        let mut out = self.clone();
        for (k, v) in overrides {
            out.options.insert((*k).to_string(), (*v).to_string());
        }
        out
    }

    /// Typed option access with a default; a malformed value is a proper
    /// [`CliError`] (the seed version panicked here, which aborted
    /// `bbmm serve` on a bad flag instead of printing usage).
    pub fn get_parse_or<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse::<T>().map_err(|_| CliError {
                flag: name.to_string(),
                message: format!("cannot parse {s:?} as {}", std::any::type_name::<T>()),
            }),
        }
    }

    /// Comma-separated f64 list option (`--noises 0.05,0.1,0.4`);
    /// `default` when the option is absent. Empty items and whitespace
    /// around items are tolerated (`"0.1, 0.2"`).
    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Result<Vec<f64>, CliError> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(str::trim)
                .filter(|tok| !tok.is_empty())
                .map(|tok| {
                    tok.parse::<f64>().map_err(|_| CliError {
                        flag: name.to_string(),
                        message: format!("cannot parse {tok:?} as f64"),
                    })
                })
                .collect(),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, CliError> {
        self.get_parse_or(name, default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, CliError> {
        self.get_parse_or(name, default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, CliError> {
        self.get_parse_or(name, default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_positional_options_flags() {
        let a = parse(&["train", "--n", "100", "--verbose", "--k=5", "extra"]);
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get("n"), Some("100"));
        assert_eq!(a.get("k"), Some("5"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_access() {
        let a = parse(&["--n", "100", "--lr", "0.1"]);
        assert_eq!(a.usize_or("n", 1).unwrap(), 100);
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.1);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn malformed_value_is_a_proper_error() {
        let a = parse(&["--n", "abc"]);
        let err = a.usize_or("n", 1).unwrap_err();
        assert_eq!(err.flag, "n");
        assert!(err.message.contains("abc"), "{err}");
        assert!(format!("{err}").starts_with("--n:"));
    }

    #[test]
    fn f64_list_parses_and_rejects() {
        let a = parse(&["--noises", "0.05, 0.1,0.4"]);
        assert_eq!(a.f64_list_or("noises", &[]).unwrap(), vec![0.05, 0.1, 0.4]);
        assert_eq!(a.f64_list_or("absent", &[1.0]).unwrap(), vec![1.0]);
        let bad = parse(&["--noises", "0.1,zebra"]);
        let err = bad.f64_list_or("noises", &[]).unwrap_err();
        assert_eq!(err.flag, "noises");
        assert!(err.message.contains("zebra"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--verbose"]);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn repeated_options_accumulate_in_order() {
        let a = parse(&["--tenant", "a=exact", "--tenant", "b=sgpr", "--tenant=c=ski"]);
        assert_eq!(a.get_all("tenant"), vec!["a=exact", "b=sgpr", "c=ski"]);
        // last occurrence still wins for scalar access
        assert_eq!(a.get("tenant"), Some("c=ski"));
        assert!(a.get_all("missing").is_empty());
    }

    #[test]
    fn with_overrides_rewrites_options() {
        let a = parse(&["serve", "--model", "exact", "--n", "100"]);
        let b = a.with_overrides(&[("model", "sgpr"), ("dataset", "wine")]);
        assert_eq!(b.get("model"), Some("sgpr"));
        assert_eq!(b.get("dataset"), Some("wine"));
        assert_eq!(b.get("n"), Some("100"));
        assert_eq!(a.get("model"), Some("exact"));
    }
}
