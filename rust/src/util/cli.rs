//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// positional arguments in order
    pub positional: Vec<String>,
    /// --key value / --key=value pairs (last occurrence wins)
    pub options: BTreeMap<String, String>,
    /// bare --flags
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed option access with a default; panics with a clear message on a
    /// malformed value (CLI misuse should fail loudly).
    pub fn get_parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            None => default,
            Some(s) => s
                .parse::<T>()
                .unwrap_or_else(|_| panic!("--{name}: cannot parse {s:?}")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get_parse_or(name, default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get_parse_or(name, default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get_parse_or(name, default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_positional_options_flags() {
        let a = parse(&["train", "--n", "100", "--verbose", "--k=5", "extra"]);
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get("n"), Some("100"));
        assert_eq!(a.get("k"), Some("5"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_access() {
        let a = parse(&["--n", "100", "--lr", "0.1"]);
        assert_eq!(a.usize_or("n", 1), 100);
        assert_eq!(a.f64_or("lr", 0.0), 0.1);
        assert_eq!(a.usize_or("missing", 7), 7);
    }

    #[test]
    #[should_panic(expected = "cannot parse")]
    fn malformed_value_panics() {
        let a = parse(&["--n", "abc"]);
        a.usize_or("n", 1);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--verbose"]);
        assert!(a.flag("verbose"));
    }
}
