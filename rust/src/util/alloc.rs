//! Debug-build heap-allocation counter — the observable behind the
//! zero-allocation claim on the mBCG iteration loop.
//!
//! In debug builds (`cfg(debug_assertions)`) the crate installs a counting
//! global allocator: every `alloc`/`realloc`/`alloc_zeroed` bumps a
//! **thread-local** counter before delegating to the system allocator.
//! [`thread_allocations`] reads the calling thread's count, so a solver
//! can snapshot it around its iteration loop and report the delta
//! (`MbcgBatchStats::loop_allocs`) without interference from concurrently
//! running tests or pool workers. Release builds keep the plain system
//! allocator; the counter then always reads 0.

use std::cell::Cell;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Heap allocations performed by **this thread** since it started
/// (always 0 in release builds, where no counting allocator is installed).
pub fn thread_allocations() -> u64 {
    ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

#[inline]
fn bump() {
    // try_with: allocations during thread teardown must not panic
    let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
}

/// System-allocator wrapper that counts allocation calls per thread.
pub struct CountingAlloc;

// SAFETY: delegates every operation to `std::alloc::System`; the counter
// bump has no effect on allocator behaviour.
unsafe impl std::alloc::GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: std::alloc::Layout) -> *mut u8 {
        bump();
        std::alloc::System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: std::alloc::Layout) {
        std::alloc::System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: std::alloc::Layout, new_size: usize) -> *mut u8 {
        bump();
        std::alloc::System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: std::alloc::Layout) -> *mut u8 {
        bump();
        std::alloc::System.alloc_zeroed(layout)
    }
}

#[cfg(debug_assertions)]
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_sees_debug_allocations() {
        let before = thread_allocations();
        let v: Vec<u64> = (0..64).collect();
        assert_eq!(v.len(), 64);
        let after = thread_allocations();
        if cfg!(debug_assertions) {
            assert!(after > before, "debug builds must count the Vec allocation");
        } else {
            assert_eq!(after, before, "release builds do not count");
        }
    }

    #[test]
    fn pure_arithmetic_allocates_nothing() {
        // warm any lazy state, then measure a no-allocation region
        let _ = thread_allocations();
        let before = thread_allocations();
        let mut acc = 0u64;
        for i in 0..1000u64 {
            acc = acc.wrapping_mul(31).wrapping_add(i);
        }
        assert!(acc != 1, "keep the loop alive");
        assert_eq!(thread_allocations(), before);
    }
}
