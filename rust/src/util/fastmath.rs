//! Branch-free vectorizable math kernels for the fused kernel mat-mul hot
//! path. `exp` via libm is a scalar call (~20–40 ns); the polynomial
//! version below autovectorizes under AVX-512 and is accurate to ~2e-10
//! relative over the range kernel evaluations use.

/// Fast `e^x` for x ∈ [−746, 710) (clamped outside), max relative error
/// ≈ 2e-10 — far below the Monte-Carlo noise floor of BBMM's estimators.
///
/// Cephes-style: x = k·ln2 + r with r ∈ [−ln2/2, ln2/2]; e^r by a degree-7
/// Taylor/minimax polynomial; scale by 2^k through exponent bits.
#[inline]
pub fn fast_exp(x: f64) -> f64 {
    const LOG2E: f64 = std::f64::consts::LOG2_E;
    const LN2_HI: f64 = 6.93147180369123816490e-01;
    const LN2_LO: f64 = 1.90821492927058770002e-10;
    // clamp to the *normal* range (2^k stays a normal float; anything
    // below −708 is ≤ 3e-308 ≈ 0 for every kernel purpose)
    let x = x.clamp(-708.0, 709.0);
    let k = (x * LOG2E + if x >= 0.0 { 0.5 } else { -0.5 }) as i64;
    let kf = k as f64;
    // r = x − k·ln2, in two pieces for accuracy
    let r = (x - kf * LN2_HI) - kf * LN2_LO;
    // e^r, degree-9 polynomial (Horner) — |r| ≤ ln2/2 ≈ 0.347,
    // truncation error ≤ r¹⁰/10! ≈ 7e-12
    let p = 1.0
        + r * (1.0
            + r * (0.5
                + r * (1.666666666666666574e-1
                    + r * (4.166666666666452278e-2
                        + r * (8.333333333331493192e-3
                            + r * (1.388888889423061626e-3
                                + r * (1.984126984200918683e-4
                                    + r * (2.480158729876093e-5
                                        + r * 2.755731922398589e-6))))))));
    // scale by 2^k via exponent bits
    let bits = ((k + 1023) as u64) << 52;
    p * f64::from_bits(bits)
}

/// Apply `out[i] = s · e^{−a·x[i]}` over a slice — the RBF tile epilogue.
#[inline]
pub fn exp_neg_scaled(x: &[f64], a: f64, s: f64, out: &mut [f64]) {
    debug_assert_eq!(x.len(), out.len());
    for i in 0..x.len() {
        out[i] = s * fast_exp(-a * x[i]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_libm_over_kernel_range() {
        // kernel args are ≤ 0 (−r²/2ℓ² or −√5r/ℓ); sweep densely
        let mut max_rel = 0.0f64;
        let mut x = -60.0;
        while x <= 1.0 {
            let got = fast_exp(x);
            let want = x.exp();
            let rel = if want > 0.0 { (got - want).abs() / want } else { 0.0 };
            max_rel = max_rel.max(rel);
            x += 0.00037;
        }
        assert!(max_rel < 5e-10, "max rel err {max_rel}");
    }

    #[test]
    fn wide_range_and_clamping() {
        assert!((fast_exp(0.0) - 1.0).abs() < 1e-12);
        assert!((fast_exp(1.0) - std::f64::consts::E).abs() < 1e-9);
        assert!(fast_exp(-800.0) >= 0.0);
        assert!(fast_exp(-800.0) < 1e-300);
        assert!(fast_exp(1000.0).is_finite()); // clamped at 709
        let big = fast_exp(700.0);
        assert!((big.ln() - 700.0).abs() < 1e-7);
    }

    #[test]
    fn exp_neg_scaled_slice() {
        let x = [0.0, 1.0, 4.0];
        let mut out = [0.0; 3];
        exp_neg_scaled(&x, 0.5, 2.0, &mut out);
        assert!((out[0] - 2.0).abs() < 1e-12);
        assert!((out[1] - 2.0 * (-0.5f64).exp()).abs() < 1e-9);
        assert!((out[2] - 2.0 * (-2.0f64).exp()).abs() < 1e-9);
    }
}
