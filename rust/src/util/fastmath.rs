//! Branch-free vectorizable math kernels for the fused kernel mat-mul hot
//! path. `exp` via libm is a scalar call (~20–40 ns); the polynomial
//! versions below run 4–8 elements per cycle through the explicit SIMD
//! arms in [`crate::tensor::simd`] (slice entry points), and the scalar
//! forms autovectorize as a fallback. Accuracy: ~2e-10 relative (f64),
//! ~1e-7 relative (f32) over the range kernel evaluations use.
//!
//! The range-reduction constants and polynomial coefficient tables are
//! `pub(crate)` so the SIMD lanes and the scalar fallbacks are *the same
//! approximation* — one source of truth, verified against libm in both
//! modules' tests.

/// `log₂ e`-scaled split of ln 2: high piece (f64). `ln 2 = LN2_HI + LN2_LO`.
pub(crate) const LN2_HI_F64: f64 = 6.93147180369123816490e-01;
/// Low piece of the two-piece ln 2 (f64).
pub(crate) const LN2_LO_F64: f64 = 1.90821492927058770002e-10;
/// Input clamp floor: keeps `2^k` a *normal* f64 (e^{−708} ≈ 3e-308).
pub(crate) const EXP_LO_F64: f64 = -708.0;
/// Input clamp ceiling: largest x with e^x finite in f64.
pub(crate) const EXP_HI_F64: f64 = 709.0;
/// Degree-9 `e^r` polynomial over |r| ≤ ln2/2, highest coefficient first
/// (Horner order) — truncation error ≤ r¹⁰/10! ≈ 7e-12.
pub(crate) const EXP_POLY_F64: [f64; 10] = [
    2.755731922398589e-6,
    2.480158729876093e-5,
    1.984126984200918683e-4,
    1.388888889423061626e-3,
    8.333333333331493192e-3,
    4.166666666666452278e-2,
    1.666666666666666574e-1,
    0.5,
    1.0,
    1.0,
];

/// High piece of the two-piece ln 2 (f32): exactly representable prefix.
pub(crate) const LN2_HI_F32: f32 = 0.693_359_375;
/// Low piece of the two-piece ln 2 (f32); note `ln 2 = HI + LO`, LO < 0.
pub(crate) const LN2_LO_F32: f32 = -2.121_944_4e-4;
/// Input clamp floor (f32): keeps `2^k` normal (Cephes MINLOGF).
pub(crate) const EXP_LO_F32: f32 = -87.336_544;
/// Input clamp ceiling (f32): keeps k ≤ 127 so `2^k` stays finite
/// (Cephes MAXLOGF — deliberately below ln(f32::MAX) ≈ 88.72 because the
/// exponent-bit scaling needs a normal `2^k`).
pub(crate) const EXP_HI_F32: f32 = 88.376_26;
/// Degree-6 `e^r` polynomial over |r| ≤ ln2/2 (Cephes expf), highest
/// coefficient first (Horner order) — ~1e-7 relative.
pub(crate) const EXP_POLY_F32: [f32; 8] = [
    1.987_569_1e-4,
    1.398_199_9e-3,
    8.333_452e-3,
    4.166_579_6e-2,
    1.666_666_5e-1,
    5.000_000_2e-1,
    1.0,
    1.0,
];

/// Fast `e^x` for x ∈ [−708, 709] (clamped outside: anything below −708
/// is ≤ 3e-308 ≈ 0 for every kernel purpose, and the clamp keeps the
/// `2^k` exponent-bit scale a normal float), max relative error ≈ 2e-10 —
/// far below the Monte-Carlo noise floor of BBMM's estimators.
///
/// Cephes-style: x = k·ln2 + r with r ∈ [−ln2/2, ln2/2]; e^r by a
/// degree-9 polynomial; scale by 2^k through exponent bits.
#[inline]
pub fn fast_exp(x: f64) -> f64 {
    const LOG2E: f64 = std::f64::consts::LOG2_E;
    let x = x.clamp(EXP_LO_F64, EXP_HI_F64);
    let k = (x * LOG2E + if x >= 0.0 { 0.5 } else { -0.5 }) as i64;
    let kf = k as f64;
    // r = x − k·ln2, in two pieces for accuracy
    let r = (x - kf * LN2_HI_F64) - kf * LN2_LO_F64;
    // e^r by Horner over the shared coefficient table (compile-time
    // unrolled; same association as the SIMD lanes)
    let mut p = EXP_POLY_F64[0];
    for &c in &EXP_POLY_F64[1..] {
        p = p * r + c;
    }
    // scale by 2^k via exponent bits
    let bits = ((k + 1023) as u64) << 52;
    p * f64::from_bits(bits)
}

/// f32 twin of [`fast_exp`]: x ∈ [−87.34, 88.38] (clamped outside), max
/// relative error ≈ 1e-7 — the Mixed-precision tile epilogue's exp.
#[inline]
pub fn fast_exp_f32(x: f32) -> f32 {
    const LOG2E: f32 = std::f32::consts::LOG2_E;
    let x = x.clamp(EXP_LO_F32, EXP_HI_F32);
    let k = (x * LOG2E + if x >= 0.0 { 0.5 } else { -0.5 }) as i32;
    let kf = k as f32;
    let r = (x - kf * LN2_HI_F32) - kf * LN2_LO_F32;
    let mut p = EXP_POLY_F32[0];
    for &c in &EXP_POLY_F32[1..] {
        p = p * r + c;
    }
    let bits = ((k + 127) as u32) << 23;
    p * f32::from_bits(bits)
}

/// In-place `x[i] = e^{x[i]}` over a whole slice — the batched form the
/// stationary kernel tiles call once per r² row. The SIMD arm (AVX2/FMA
/// or NEON, runtime dispatched) covers the lane-aligned prefix; the tail
/// (and the scalar-dispatch case) falls back to [`fast_exp`].
#[inline]
pub fn fast_exp_slice(x: &mut [f64]) {
    let done = crate::tensor::simd::exp_f64_prefix(x);
    for v in &mut x[done..] {
        *v = fast_exp(*v);
    }
}

/// f32 twin of [`fast_exp_slice`] (twice the SIMD lane width).
#[inline]
pub fn fast_exp_slice_f32(x: &mut [f32]) {
    let done = crate::tensor::simd::exp_f32_prefix(x);
    for v in &mut x[done..] {
        *v = fast_exp_f32(*v);
    }
}

/// Apply `out[i] = s · e^{−a·x[i]}` over a slice — the RBF tile epilogue,
/// batched: one multiply pass to form the arguments, one vectorised exp
/// sweep, one scale pass.
#[inline]
pub fn exp_neg_scaled(x: &[f64], a: f64, s: f64, out: &mut [f64]) {
    debug_assert_eq!(x.len(), out.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o = -a * v;
    }
    fast_exp_slice(out);
    for o in out.iter_mut() {
        *o *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_libm_over_kernel_range() {
        // kernel args are ≤ 0 (−r²/2ℓ² or −√5r/ℓ); sweep densely
        let mut max_rel = 0.0f64;
        let mut x = -60.0;
        while x <= 1.0 {
            let got = fast_exp(x);
            let want = x.exp();
            let rel = if want > 0.0 { (got - want).abs() / want } else { 0.0 };
            max_rel = max_rel.max(rel);
            x += 0.00037;
        }
        assert!(max_rel < 5e-10, "max rel err {max_rel}");
    }

    #[test]
    fn f32_matches_libm_over_kernel_range() {
        let mut max_rel = 0.0f32;
        let mut x = -40.0f32;
        while x <= 1.0 {
            let got = fast_exp_f32(x);
            let want = x.exp();
            let rel = if want > 0.0 { (got - want).abs() / want } else { 0.0 };
            max_rel = max_rel.max(rel);
            x += 0.0113;
        }
        assert!(max_rel < 3e-7, "max rel err {max_rel}");
        // clamping behaviour mirrors the f64 version
        assert!(fast_exp_f32(-1.0e4).is_finite());
        assert!(fast_exp_f32(-1.0e4) < 1e-37);
        assert!(fast_exp_f32(1.0e4).is_finite()); // clamped at MAXLOGF
    }

    #[test]
    fn wide_range_and_clamping() {
        assert!((fast_exp(0.0) - 1.0).abs() < 1e-12);
        assert!((fast_exp(1.0) - std::f64::consts::E).abs() < 1e-9);
        assert!(fast_exp(-800.0) >= 0.0);
        assert!(fast_exp(-800.0) < 1e-300);
        assert!(fast_exp(1000.0).is_finite()); // clamped at 709
        let big = fast_exp(700.0);
        assert!((big.ln() - 700.0).abs() < 1e-7);
    }

    #[test]
    fn slice_exp_matches_scalar_and_libm() {
        // odd length so both the SIMD prefix and the scalar tail run
        let mut xs: Vec<f64> = (0..203).map(|i| -50.0 + 0.29 * i as f64).collect();
        let want: Vec<f64> = xs.iter().map(|&x| x.exp()).collect();
        fast_exp_slice(&mut xs);
        for (i, (&got, &w)) in xs.iter().zip(&want).enumerate() {
            assert!((got - w).abs() < 5e-10 * w.max(1e-300), "entry {i}: {got} vs {w}");
        }
        let mut xs32: Vec<f32> = (0..101).map(|i| -30.0 + 0.31 * i as f32).collect();
        let want32: Vec<f32> = xs32.iter().map(|&x| x.exp()).collect();
        fast_exp_slice_f32(&mut xs32);
        for (i, (&got, &w)) in xs32.iter().zip(&want32).enumerate() {
            assert!((got - w).abs() < 3e-7 * w.max(1e-30), "entry {i}: {got} vs {w}");
        }
    }

    #[test]
    fn exp_neg_scaled_slice() {
        let x = [0.0, 1.0, 4.0];
        let mut out = [0.0; 3];
        exp_neg_scaled(&x, 0.5, 2.0, &mut out);
        assert!((out[0] - 2.0).abs() < 1e-12);
        assert!((out[1] - 2.0 * (-0.5f64).exp()).abs() < 1e-9);
        assert!((out[2] - 2.0 * (-2.0f64).exp()).abs() < 1e-9);
    }
}
