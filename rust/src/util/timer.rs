//! Wall-clock timing helpers used by the benchmark harness and trainer.

use std::time::Instant;

/// Simple wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    /// Seconds elapsed since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds elapsed since start.
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    /// Restart and return the elapsed seconds up to now.
    pub fn lap_s(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_s())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        let a = t.elapsed_s();
        let b = t.elapsed_s();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
