//! Per-thread reusable scratch buffers for the fused kernel-tile paths.
//!
//! The streaming covariance products need a few rows of r²/kernel-row
//! workspace per worker. Allocating those inside every product call puts
//! heap traffic in the mBCG iteration loop, so each thread keeps one
//! grow-only `Vec<f64>` here: the first product on a thread sizes it, and
//! every later call on that thread (pool workers are persistent —
//! [`crate::util::par`]) is allocation-free.
//!
//! Regions must not nest on one thread (a `with` inside a `with` would
//! alias the buffer); the kernel operators take a single buffer per
//! parallel chunk and split it, which keeps that invariant locally
//! checkable.

use std::cell::RefCell;

thread_local! {
    static SCRATCH: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` with a scratch slice of length `len`, reusing this thread's
/// buffer (grow-only; no shrink, no per-call allocation once warm). The
/// slice contents are **unspecified** — callers overwrite what they read.
/// Panics if called re-entrantly on one thread.
pub fn with<R>(len: usize, f: impl FnOnce(&mut [f64]) -> R) -> R {
    SCRATCH.with(|cell| {
        let mut buf = cell
            .try_borrow_mut()
            .expect("util::scratch::with must not nest on one thread");
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        f(&mut buf[..len])
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_reuses_capacity() {
        with(128, |buf| {
            assert_eq!(buf.len(), 128);
            buf[0] = 7.0;
        });
        let before = crate::util::alloc::thread_allocations();
        with(64, |buf| {
            assert_eq!(buf.len(), 64);
        });
        with(128, |buf| {
            assert_eq!(buf.len(), 128);
        });
        assert_eq!(
            crate::util::alloc::thread_allocations(),
            before,
            "warm scratch must not allocate"
        );
    }
}
