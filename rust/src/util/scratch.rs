//! Per-thread reusable scratch buffers for the fused kernel-tile paths.
//!
//! The streaming covariance products need a few rows of r²/kernel-row
//! workspace per worker. Allocating those inside every product call puts
//! heap traffic in the mBCG iteration loop, so each thread keeps one
//! grow-only `Vec<f64>` here: the first product on a thread sizes it, and
//! every later call on that thread (pool workers are persistent —
//! [`crate::util::par`]) is allocation-free.
//!
//! Regions must not nest on one thread (a `with` inside a `with` would
//! alias the buffer); the kernel operators take a single buffer per
//! parallel chunk and split it, which keeps that invariant locally
//! checkable.

use std::cell::RefCell;

thread_local! {
    static SCRATCH: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
    // separate slot for f32 staging so a `with_f32` can run while the f64
    // region is NOT held (and vice versa) without tripping the no-nest
    // guard — the mixed-precision path stages inputs before fanning out
    static SCRATCH_F32: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` with a scratch slice of length `len`, reusing this thread's
/// buffer (grow-only; no shrink, no per-call allocation once warm). The
/// slice contents are **unspecified** — callers overwrite what they read.
/// Panics if called re-entrantly on one thread.
pub fn with<R>(len: usize, f: impl FnOnce(&mut [f64]) -> R) -> R {
    SCRATCH.with(|cell| {
        let mut buf = cell
            .try_borrow_mut()
            .expect("util::scratch::with must not nest on one thread");
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        f(&mut buf[..len])
    })
}

/// f32 twin of [`with`], backed by its **own** per-thread buffer — the
/// mixed-precision tile paths hold an f64 region and an f32 region on the
/// same worker thread simultaneously (f32 tiles, f64 accumulators), which
/// the single-slot guard would otherwise forbid. The same no-nest rule
/// applies *within* the f32 slot.
pub fn with_f32<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    SCRATCH_F32.with(|cell| {
        let mut buf = cell
            .try_borrow_mut()
            .expect("util::scratch::with_f32 must not nest on one thread");
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        f(&mut buf[..len])
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_reuses_capacity() {
        with(128, |buf| {
            assert_eq!(buf.len(), 128);
            buf[0] = 7.0;
        });
        let before = crate::util::alloc::thread_allocations();
        with(64, |buf| {
            assert_eq!(buf.len(), 64);
        });
        with(128, |buf| {
            assert_eq!(buf.len(), 128);
        });
        assert_eq!(
            crate::util::alloc::thread_allocations(),
            before,
            "warm scratch must not allocate"
        );
    }

    #[test]
    fn f32_slot_is_independent_of_f64_slot() {
        // holding the f64 region while opening the f32 region must NOT
        // trip the no-nest guard — that's the mixed-tile usage pattern
        with(32, |f64buf| {
            f64buf[0] = 1.0;
            with_f32(16, |f32buf| {
                assert_eq!(f32buf.len(), 16);
                f32buf[0] = 2.0;
            });
            assert_eq!(f64buf[0], 1.0);
        });
        let before = crate::util::alloc::thread_allocations();
        with_f32(16, |buf| assert_eq!(buf.len(), 16));
        assert_eq!(
            crate::util::alloc::thread_allocations(),
            before,
            "warm f32 scratch must not allocate"
        );
    }
}
