//! In-tree benchmark harness (criterion is not resolvable offline).
//!
//! Provides warmup + repeated timing with median/mean/min reporting, and a
//! fixed-width table printer used by the figure-regeneration binaries so
//! their output reads like the paper's tables.

use crate::util::Timer;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
}

impl BenchResult {
    pub fn median_s(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if s.is_empty() {
            return f64::NAN;
        }
        let mid = s.len() / 2;
        if s.len() % 2 == 0 {
            (s[mid - 1] + s[mid]) / 2.0
        } else {
            s[mid]
        }
    }

    pub fn mean_s(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }

    pub fn min_s(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

/// Benchmark a closure: `warmup` unmeasured runs, then `samples` measured
/// runs (at least one each). Prints a one-line summary.
pub fn bench(name: &str, warmup: usize, samples: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup.max(1) {
        f();
    }
    let mut times = Vec::with_capacity(samples.max(1));
    for _ in 0..samples.max(1) {
        let t = Timer::start();
        f();
        times.push(t.elapsed_s());
    }
    let res = BenchResult {
        name: name.to_string(),
        samples: times,
    };
    println!(
        "bench {:40} median {:>10}  mean {:>10}  min {:>10}  (n={})",
        res.name,
        fmt_duration(res.median_s()),
        fmt_duration(res.mean_s()),
        fmt_duration(res.min_s()),
        res.samples.len()
    );
    res
}

/// Adaptive benchmark: keeps sampling until `budget_s` seconds are spent
/// (minimum 3 samples) — good for cases whose runtime varies by 1000×.
pub fn bench_budget(name: &str, budget_s: f64, mut f: impl FnMut()) -> BenchResult {
    f(); // warmup
    let total = Timer::start();
    let mut times = Vec::new();
    while times.len() < 3 || (total.elapsed_s() < budget_s && times.len() < 50) {
        let t = Timer::start();
        f();
        times.push(t.elapsed_s());
    }
    let res = BenchResult {
        name: name.to_string(),
        samples: times,
    };
    println!(
        "bench {:40} median {:>10}  mean {:>10}  min {:>10}  (n={})",
        res.name,
        fmt_duration(res.median_s()),
        fmt_duration(res.mean_s()),
        fmt_duration(res.min_s()),
        res.samples.len()
    );
    res
}

/// Human duration formatting.
pub fn fmt_duration(s: f64) -> String {
    if !s.is_finite() {
        "n/a".to_string()
    } else if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

/// Fixed-width table printer for figure outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$} | ", cell, w = widths[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }

    /// Write the table (and a CSV twin) under results/.
    pub fn save(&self, stem: &str) -> std::io::Result<()> {
        std::fs::create_dir_all("results")?;
        std::fs::write(format!("results/{stem}.txt"), self.to_string())?;
        let mut csv = self.headers.join(",");
        csv.push('\n');
        for row in &self.rows {
            csv.push_str(&row.join(","));
            csv.push('\n');
        }
        std::fs::write(format!("results/{stem}.csv"), csv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let r = bench("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.samples.len(), 5);
        assert!(r.median_s() >= 0.0);
        assert!(r.min_s() <= r.mean_s() * 1.0001);
    }

    #[test]
    fn median_odd_even() {
        let r = BenchResult {
            name: "x".into(),
            samples: vec![3.0, 1.0, 2.0],
        };
        assert_eq!(r.median_s(), 2.0);
        let r2 = BenchResult {
            name: "x".into(),
            samples: vec![4.0, 1.0, 2.0, 3.0],
        };
        assert_eq!(r2.median_s(), 2.5);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(5e-9).ends_with("ns"));
        assert!(fmt_duration(5e-6).ends_with("µs"));
        assert!(fmt_duration(5e-3).ends_with("ms"));
        assert!(fmt_duration(5.0).ends_with("s"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["dataset", "n", "speedup"]);
        t.row(&["wine".into(), "1599".into(), "4.2x".into()]);
        t.row(&["skillcraft".into(), "3338".into(), "12.9x".into()]);
        let s = t.to_string();
        assert!(s.contains("dataset"));
        assert!(s.lines().count() == 4);
        let widths: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert_eq!(widths[0], widths[2]);
    }

    #[test]
    #[should_panic(expected = "table width mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
