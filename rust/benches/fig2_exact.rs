//! Bench: Figure 2 (left) — exact-GP training iteration, BBMM vs Cholesky.
//! Quick sizes by default; set BBMM_BENCH_FULL=1 for paper-scale n.

use bbmm_gp::bench::{bench_budget, Table};
use bbmm_gp::data::synthetic::generate_sized;
use bbmm_gp::gp::mll::{BbmmEngine, CholeskyEngine, InferenceEngine};
use bbmm_gp::kernels::{DenseKernelOp, Rbf};

fn main() {
    let full = std::env::var("BBMM_BENCH_FULL").is_ok();
    let sizes: &[usize] = if full {
        &[500, 1000, 2000, 3500]
    } else {
        &[300, 600, 1200]
    };
    let mut table = Table::new(&["n", "chol_s", "bbmm_s", "speedup"]);
    for &n in sizes {
        let ds = generate_sized("bench_exact", n, 6, 1);
        let y = ds.y_train.clone();
        let op = DenseKernelOp::new(ds.x_train.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.05);
        let chol = bench_budget(&format!("exact/cholesky/n{n}"), 2.0, || {
            let _ = CholeskyEngine.mll_and_grad(&op, &y);
        });
        let mut engine = BbmmEngine::default();
        let bbmm = bench_budget(&format!("exact/bbmm/n{n}"), 2.0, || {
            let _ = engine.mll_and_grad(&op, &y);
        });
        table.row(&[
            n.to_string(),
            format!("{:.4}", chol.median_s()),
            format!("{:.4}", bbmm.median_s()),
            format!("{:.1}x", chol.median_s() / bbmm.median_s()),
        ]);
    }
    table.print();
    table.save("bench_fig2_exact").ok();
}
