//! Bench: **batched vs sequential sweep training step** — the tentpole
//! payoff measured. b hyperparameter candidates share one covariance `K`
//! with per-candidate σ² (a noise sweep / multi-restart over one dataset);
//! the unit of work is ONE Adam step's evaluation: nmll + gradient for
//! every candidate.
//!
//! The sequential baseline loops a scalar [`BbmmEngine`] over the b
//! candidates — paying b× the kernel-row generation per CG iteration, b
//! pivoted-Cholesky preconditioner builds, and 2·b covariance passes per
//! gradient parameter. The batched path is ONE
//! [`BatchBbmmEngine::mll_and_grad_batch`] call: one fused `K·[D₁ … D_b]`
//! per shared iteration, one preconditioner factor, one fused gradient
//! pass per parameter. Identical numerics (shared probe RNG — asserted
//! before timing), so the gap is purely the amortised operator work.
//!
//! Grid: n ∈ {2k, 8k}, b ∈ {4, 16}. Writes `results/BENCH_train.json`
//! (the CI perf artifact) plus the usual table/CSV pair.
//! `BBMM_BENCH_QUICK=1` cuts per-case samples, not the grid, so the
//! artifact schema is stable across environments.

use bbmm_gp::bench::{bench, Table};
use bbmm_gp::gp::mll::{BatchBbmmEngine, BatchInferenceEngine, BbmmEngine, InferenceEngine};
use bbmm_gp::kernels::{KernelCovOp, Rbf};
use bbmm_gp::linalg::op::{AddedDiagOp, BatchOp};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::par;
use bbmm_gp::util::Rng;

const CG_ITERS: usize = 4;
const PROBES: usize = 2;
const PRECOND_RANK: usize = 5;

struct Case {
    n: usize,
    b: usize,
    sequential_s: f64,
    batched_s: f64,
    batched_products: usize,
    sequential_products: usize,
}

fn main() {
    let quick = std::env::var("BBMM_BENCH_QUICK").is_ok();
    let samples = if quick { 1 } else { 3 };
    let sizes = [2_000usize, 8_000];
    let batches = [4usize, 16];
    println!(
        "batch_train: cg_iters={CG_ITERS} probes={PROBES} rank={PRECOND_RANK} \
         samples={samples} threads={}\n",
        par::num_threads()
    );

    let mut cases = Vec::new();
    let mut table = Table::new(&["n", "b", "sequential_s", "batched_s", "speedup"]);
    for &n in &sizes {
        let mut rng = Rng::new(n as u64);
        let x = Mat::from_fn(n, 4, |_, _| rng.uniform_in(-1.0, 1.0));
        let y: Vec<f64> = (0..n).map(|i| (3.0 * x.get(i, 0)).sin() + 0.05 * rng.normal()).collect();
        let cov = KernelCovOp::new(x, Box::new(Rbf::new(0.5, 1.0)));
        let sigma2s: Vec<f64> = (0..16).map(|i| 0.05 * (1.0 + 0.25 * i as f64)).collect();
        for &b in &batches {
            let batch = BatchOp::shared(&cov, sigma2s[..b].to_vec());

            // correctness gate before timing: batched == sequential (same
            // probe RNG stream) for every candidate's nmll and gradient
            let (batched_products, sequential_products) = {
                let mut be = BatchBbmmEngine::new(CG_ITERS, PROBES, PRECOND_RANK, 42);
                let got = be.mll_and_grad_batch(&batch, &y);
                let mut se = BbmmEngine::new(CG_ITERS, PROBES, PRECOND_RANK, 42);
                for (k, &s2) in sigma2s[..b].iter().enumerate() {
                    let op = AddedDiagOp::new(&cov, s2);
                    let want = se.mll_and_grad(&op, &y);
                    assert!(
                        (got[k].nmll - want.nmll).abs() < 1e-8,
                        "n={n} b={b} candidate {k} diverged: {} vs {}",
                        got[k].nmll,
                        want.nmll
                    );
                    for p in 0..want.grad.len() {
                        assert!((got[k].grad[p] - want.grad[p]).abs() < 1e-8);
                    }
                }
                (be.last_stats.batched_products, be.last_stats.system_iterations)
            };

            let sequential = bench(&format!("train/sequential/n{n}/b{b}"), 1, samples, || {
                let mut se = BbmmEngine::new(CG_ITERS, PROBES, PRECOND_RANK, 42);
                for &s2 in &sigma2s[..b] {
                    let op = AddedDiagOp::new(&cov, s2);
                    let _ = se.mll_and_grad(&op, &y);
                }
            });
            let batched = bench(&format!("train/batched/n{n}/b{b}"), 1, samples, || {
                let mut be = BatchBbmmEngine::new(CG_ITERS, PROBES, PRECOND_RANK, 42);
                let _ = be.mll_and_grad_batch(&batch, &y);
            });
            let (ss, bs) = (sequential.median_s(), batched.median_s());
            table.row(&[
                n.to_string(),
                b.to_string(),
                format!("{ss:.4}"),
                format!("{bs:.4}"),
                format!("{:.2}x", ss / bs),
            ]);
            cases.push(Case {
                n,
                b,
                sequential_s: ss,
                batched_s: bs,
                batched_products,
                sequential_products,
            });
        }
    }
    println!();
    table.print();
    table.save("bench_batch_train").ok();
    write_json(&cases).expect("write BENCH_train.json");
    println!(
        "\nwrote results/BENCH_train.json — expect batched < sequential as b grows \
         (kernel-row generation, preconditioner build, and gradient passes amortise)"
    );
}

/// Hand-rolled JSON (no serde offline): the schema CI archives as the
/// perf-trajectory artifact.
fn write_json(cases: &[Case]) -> std::io::Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"batch_train\",\n");
    out.push_str(&format!("  \"threads\": {},\n", par::num_threads()));
    out.push_str(&format!("  \"cg_iters\": {CG_ITERS},\n"));
    out.push_str(&format!("  \"probes\": {PROBES},\n"));
    out.push_str(&format!("  \"precond_rank\": {PRECOND_RANK},\n"));
    out.push_str("  \"cases\": [\n");
    for (i, c) in cases.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"n\": {}, \"b\": {}, \"sequential_s\": {:.6}, \"batched_s\": {:.6}, \
             \"speedup\": {:.3}, \"batched_products\": {}, \"sequential_products\": {}}}{}\n",
            c.n,
            c.b,
            c.sequential_s,
            c.batched_s,
            c.sequential_s / c.batched_s,
            c.batched_products,
            c.sequential_products,
            if i + 1 < cases.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n");
    out.push_str("}\n");
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_train.json", out)
}
