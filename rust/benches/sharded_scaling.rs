//! Bench: dense (monolithic) vs sharded kernel operator as n grows.
//!
//! Neither operator ever materialises the n×n kernel matrix (that would be
//! 8 GB of f64 at n = 32k) — kernel rows are generated on the fly and
//! contracted immediately, so peak memory stays O(n·t + tile·n). What this
//! bench isolates is the *organisation* of that work: one monolithic
//! parallel-for (DenseKernelOp) vs per-shard tile queues with static
//! striping + work stealing (ShardedKernelOp), plus the solver-level
//! shard-assembled product used by `mbcg_sharded`.
//!
//! Default sizes n ∈ {2k, 8k, 32k}; BBMM_BENCH_QUICK=1 drops the 32k case.

use bbmm_gp::bench::{bench_budget, Table};
use bbmm_gp::kernels::{DenseKernelOp, KernelCovOp, Rbf, ShardedKernelOp};
use bbmm_gp::linalg::mbcg::{mbcg, mbcg_sharded, MbcgOptions};
use bbmm_gp::linalg::op::{solve, AddedDiagOp, LinearOp, SolveOptions};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::par;
use bbmm_gp::util::Rng;

const T_PROBES: usize = 8;

fn main() {
    let quick = std::env::var("BBMM_BENCH_QUICK").is_ok();
    let sizes: &[usize] = if quick {
        &[2_000, 8_000]
    } else {
        &[2_000, 8_000, 32_000]
    };
    let shards = par::num_threads().max(2);
    println!(
        "sharded_scaling: t={T_PROBES} shards={shards} threads={}\n",
        par::num_threads()
    );

    let mut table = Table::new(&["n", "dense_s", "sharded_s", "shards", "speedup"]);
    for &n in sizes {
        let mut rng = Rng::new(n as u64);
        let x = Mat::from_fn(n, 4, |_, _| rng.uniform_in(-1.0, 1.0));
        let dense = DenseKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.05);
        let sharded = ShardedKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), 0.05, shards);
        let m = Mat::from_fn(n, T_PROBES, |_, _| rng.normal());

        // one-time correctness gate before timing anything
        if n == sizes[0] {
            let diff = sharded.matmul(&m).max_abs_diff(&dense.matmul(&m));
            assert!(diff < 1e-10, "sharded operator diverged: {diff}");
        }

        let d = bench_budget(&format!("op/dense/n{n}"), 2.0, || {
            let _ = dense.matmul(&m);
        });
        let s = bench_budget(&format!("op/sharded/n{n}"), 2.0, || {
            let _ = sharded.matmul(&m);
        });
        table.row(&[
            n.to_string(),
            format!("{:.4}", d.median_s()),
            format!("{:.4}", s.median_s()),
            shards.to_string(),
            format!("{:.2}x", d.median_s() / s.median_s()),
        ]);
    }
    table.print();
    table.save("bench_sharded_scaling").ok();

    // solver integration: monolithic mBCG vs the shard-assembled mmm_A
    // path, fixed iteration budget so both do identical numerical work
    let n = 8_000;
    let mut rng = Rng::new(77);
    let x = Mat::from_fn(n, 4, |_, _| rng.uniform_in(-1.0, 1.0));
    let dense = DenseKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.05);
    let sharded = ShardedKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), 0.05, shards);
    let b = Mat::from_fn(n, 1 + T_PROBES, |_, _| rng.normal());
    let opts = MbcgOptions {
        max_iters: 10,
        tol: 0.0,
        n_solve_only: 1,
    };
    let mut solver = Table::new(&["path", "n", "p", "median_s"]);
    let mono = bench_budget("mbcg/monolithic/n8000", 3.0, || {
        let _ = mbcg(|m| dense.matmul(m), &b, |m| m.clone(), &opts);
    });
    let shrd = bench_budget("mbcg/sharded/n8000", 3.0, || {
        let _ = mbcg_sharded(&sharded, &b, |m| m.clone(), &opts);
    });
    solver.row(&[
        "monolithic".into(),
        n.to_string(),
        "10".into(),
        format!("{:.4}", mono.median_s()),
    ]);
    solver.row(&[
        "sharded".into(),
        n.to_string(),
        "10".into(),
        format!("{:.4}", shrd.median_s()),
    ]);
    println!();
    solver.print();
    solver.save("bench_sharded_mbcg").ok();

    // operator-algebra dispatch overhead: the same solve numerics through
    // (a) a raw closure over the fused operator, (b) the generic dispatcher
    // on that operator (&dyn LinearOp), (c) the dispatcher on an explicit
    // AddedDiag(KernelCov) composition. precond_rank = 0 and a fixed
    // iteration budget make the numerical work identical, so any gap is
    // the cost of the algebra's indirection — measured, not assumed.
    let n = 4_000;
    let mut rng = Rng::new(99);
    let x = Mat::from_fn(n, 4, |_, _| rng.uniform_in(-1.0, 1.0));
    let dense = DenseKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.05);
    let composed = AddedDiagOp::new(KernelCovOp::new(x, Box::new(Rbf::new(0.5, 1.0))), 0.05);
    let b = Mat::from_fn(n, T_PROBES, |_, _| rng.normal());
    let fixed = MbcgOptions {
        max_iters: 10,
        tol: 0.0,
        n_solve_only: T_PROBES,
    };
    let dispatch_opts = SolveOptions {
        max_iters: 10,
        tol: 0.0,
        precond_rank: 0,
    };
    // correctness gate: dispatcher output equals the raw-closure output
    {
        let want = mbcg(|m| dense.matmul(m), &b, |m| m.clone(), &fixed).solves;
        let got = solve(&composed as &dyn LinearOp, &b, &dispatch_opts);
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-10, "composed solve diverged: {diff}");
    }
    let mut overhead = Table::new(&["path", "n", "p", "median_s"]);
    let raw = bench_budget("solve/raw-closure/n4000", 3.0, || {
        let _ = mbcg(|m| dense.matmul(m), &b, |m| m.clone(), &fixed);
    });
    let dispatched = bench_budget("solve/dispatcher-dense/n4000", 3.0, || {
        let _ = solve(&dense as &dyn LinearOp, &b, &dispatch_opts);
    });
    let algebra = bench_budget("solve/dispatcher-composed/n4000", 3.0, || {
        let _ = solve(&composed as &dyn LinearOp, &b, &dispatch_opts);
    });
    for (name, r) in [
        ("raw-closure", &raw),
        ("dispatcher-dense", &dispatched),
        ("dispatcher-composed", &algebra),
    ] {
        overhead.row(&[
            name.into(),
            n.to_string(),
            "10".into(),
            format!("{:.4}", r.median_s()),
        ]);
    }
    println!();
    overhead.print();
    overhead.save("bench_op_dispatch").ok();
    println!(
        "\ndispatch overhead: composed/raw = {:.3}x (expect ~1.0 — the algebra adds \
         one virtual call + one axpy pass per iteration)",
        algebra.median_s() / raw.median_s()
    );
    println!("\nshape check: sharded ≈ dense at small n (scheduler overhead), ≥ at large n");
}
