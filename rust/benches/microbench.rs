//! Microbenchmarks of the substrate hot paths — the §Perf profiling input:
//! dense GEMM, fused kernel mat-mul vs materialise-then-multiply, Toeplitz
//! FFT mat-vec, pivoted Cholesky build, and a single mBCG iteration.

use bbmm_gp::bench::{bench_budget, Table};
use bbmm_gp::kernels::{DenseKernelOp, Rbf};
use bbmm_gp::linalg::op::LinearOp;
use bbmm_gp::linalg::pivoted_cholesky::pivoted_cholesky;
use bbmm_gp::linalg::toeplitz::ToeplitzOp;
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::Rng;

fn main() {
    let mut rng = Rng::new(21);
    let mut table = Table::new(&["op", "size", "median_s", "gflops"]);

    // dense GEMM
    for &n in &[256usize, 512, 1024] {
        let a = Mat::from_fn(n, n, |_, _| rng.normal());
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let r = bench_budget(&format!("gemm/{n}"), 1.5, || {
            let _ = a.matmul(&b);
        });
        let gflops = 2.0 * (n as f64).powi(3) / r.median_s() / 1e9;
        table.row(&[
            "gemm".into(),
            format!("{n}x{n}x{n}"),
            format!("{:.4}", r.median_s()),
            format!("{gflops:.2}"),
        ]);
    }

    // fused kernel mat-mul vs materialise + multiply
    for &n in &[1000usize, 3000] {
        let x = Mat::from_fn(n, 6, |_, _| rng.uniform_in(-1.0, 1.0));
        let op = DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), 0.05);
        let v = Mat::from_fn(n, 11, |_, _| rng.normal());
        let fused = bench_budget(&format!("kernel_matmul_fused/{n}"), 1.5, || {
            let _ = op.matmul(&v);
        });
        let materialise = bench_budget(&format!("kernel_matmul_dense/{n}"), 1.5, || {
            let k = op.dense();
            let _ = k.matmul(&v);
        });
        table.row(&[
            "kmm_fused".into(),
            n.to_string(),
            format!("{:.4}", fused.median_s()),
            "-".into(),
        ]);
        table.row(&[
            "kmm_dense".into(),
            n.to_string(),
            format!("{:.4}", materialise.median_s()),
            "-".into(),
        ]);
    }

    // Toeplitz FFT mat-vec
    for &m in &[4096usize, 65536] {
        let col: Vec<f64> = (0..m).map(|i| (-0.5 * (i as f64 * 1e-3).powi(2)).exp()).collect();
        let t = ToeplitzOp::new(col);
        let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let r = bench_budget(&format!("toeplitz_matvec/{m}"), 1.0, || {
            let _ = t.matvec(&v);
        });
        table.row(&[
            "toeplitz_mv".into(),
            m.to_string(),
            format!("{:.5}", r.median_s()),
            "-".into(),
        ]);
    }

    // pivoted Cholesky (rank 5) on a 3000-point kernel — factor the
    // *noise-free* part, as the §4.1 preconditioner build does (the full
    // operator's diag/row now include σ²; see LinearOp::noise_split)
    {
        let n = 3000;
        let x = Mat::from_fn(n, 4, |_, _| rng.uniform_in(-1.0, 1.0));
        let op = DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), 0.05);
        let cov = op.cov();
        let diag = cov.diag();
        let r = bench_budget("pivoted_cholesky_rank5/3000", 1.5, || {
            let _ = pivoted_cholesky(&diag, |i| cov.row(i), 5, 0.0);
        });
        table.row(&[
            "pivchol_k5".into(),
            n.to_string(),
            format!("{:.4}", r.median_s()),
            "-".into(),
        ]);
    }

    // one mBCG iteration ≈ one fused matmul + O(nt): measure 20-iteration call
    {
        let n = 2000;
        let x = Mat::from_fn(n, 4, |_, _| rng.uniform_in(-1.0, 1.0));
        let op = DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), 0.05);
        let b = Mat::from_fn(n, 11, |_, _| rng.normal());
        let r = bench_budget("mbcg_p20_t11/2000", 2.0, || {
            let _ = bbmm_gp::linalg::mbcg::mbcg(
                |m| op.matmul(m),
                &b,
                |m| m.clone(),
                &bbmm_gp::linalg::mbcg::MbcgOptions {
                    max_iters: 20,
                    tol: 0.0,
                    n_solve_only: 0,
                },
            );
        });
        table.row(&[
            "mbcg_p20".into(),
            n.to_string(),
            format!("{:.4}", r.median_s()),
            "-".into(),
        ]);
    }

    println!();
    table.print();
    table.save("microbench").ok();
}
