//! Bench: **in-process vs multi-process shard placement** for the
//! partitioned-kernel mBCG product loop (Wang et al. 2019 §3: broadcast
//! the skinny RHS, gather per-shard partials — O(n·t) traffic per
//! iteration regardless of worker count).
//!
//! Both placements run the identical fixed-iteration mBCG solve over the
//! identical shard partition; the only variable is where shard rows are
//! generated and contracted — the calling process's thread pool vs forked
//! `bbmm shard-worker` processes on the wire protocol. Parity is gated to
//! 1e-8 before anything is timed.
//!
//! Grid: n ∈ {32768, 131072} × workers ∈ {1, 2, 4} (quick mode:
//! n = 2048, workers ∈ {1, 2} — CI-sized, where the expectation is
//! parity-not-regression; process parallelism pays off at the full
//! sizes on multi-core hosts). Writes `results/BENCH_dist.json` (the CI
//! perf artifact; `"b"` carries the worker count) plus the table/CSV
//! pair.

use bbmm_gp::bench::{bench, Table};
use bbmm_gp::kernels::{Rbf, ShardedKernelOp};
use bbmm_gp::linalg::mbcg::{mbcg_op, MbcgOptions};
use bbmm_gp::runtime::dist::{MultiProcessBackend, ShardBackend, WorkerLaunch};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::par;
use bbmm_gp::util::Rng;
use std::sync::Arc;

const T_COLS: usize = 8;
const ITERS: usize = 10;
const WORKER_BUDGET_MB: usize = 512;

struct Case {
    n: usize,
    workers: usize,
    inproc_s: f64,
    proc_s: f64,
    speedup: f64,
}

fn main() {
    let quick = std::env::var("BBMM_BENCH_QUICK").is_ok();
    let sizes: &[usize] = if quick { &[2_048] } else { &[32_768, 131_072] };
    let worker_grid: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    let samples = if quick { 2 } else { 3 };
    let shards = par::num_threads().max(4);
    println!(
        "dist_scaling: t={T_COLS} iters={ITERS} shards={shards} threads={}\n",
        par::num_threads()
    );

    let launch = WorkerLaunch {
        exe: env!("CARGO_BIN_EXE_bbmm").into(),
        ..WorkerLaunch::default()
    };
    let opts = MbcgOptions {
        max_iters: ITERS,
        tol: 0.0,
        n_solve_only: T_COLS,
    };
    let mut cases = Vec::new();
    let mut table = Table::new(&["n", "workers", "inproc_s", "proc_s", "speedup"]);
    for &n in sizes {
        let mut rng = Rng::new(n as u64);
        let x = Mat::from_fn(n, 3, |_, _| rng.uniform_in(-1.0, 1.0));
        let b = Mat::from_fn(n, T_COLS, |_, _| rng.normal());
        let inproc = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.05, shards);
        let reference = mbcg_op(&inproc, &b, |m| m.clone(), &opts);
        let in_t = bench(&format!("mbcg/inproc/n{n}"), 1, samples, || {
            let _ = mbcg_op(&inproc, &b, |m| m.clone(), &opts);
        });
        for &w in worker_grid {
            let kernel = Rbf::new(0.5, 1.0);
            let proc = MultiProcessBackend::launch(
                x.clone(),
                &kernel,
                0.05,
                shards,
                w,
                WORKER_BUDGET_MB,
                launch.clone(),
            )
            .expect("fork shard workers");
            let routed = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.05, shards)
                .with_backend(Arc::new(proc));

            // parity gate before timing: the distributed placement must
            // reproduce the in-process solve to 1e-8 relative
            let got = mbcg_op(&routed, &b, |m| m.clone(), &opts);
            let scale = reference.solves.fro_norm().max(1.0);
            let diff = got.solves.max_abs_diff(&reference.solves) / scale;
            assert!(diff < 1e-8, "n={n} workers={w}: placement diverged {diff}");

            let p_t = bench(&format!("mbcg/proc{w}/n{n}"), 1, samples, || {
                let _ = mbcg_op(&routed, &b, |m| m.clone(), &opts);
            });
            let restarts = routed.backend().unwrap().stats().restarts;
            assert_eq!(restarts, 0, "n={n} workers={w}: workers crashed during the bench");
            drop(routed); // shuts the worker fleet down before the next config

            let speedup = in_t.median_s() / p_t.median_s();
            table.row(&[
                n.to_string(),
                w.to_string(),
                format!("{:.4}", in_t.median_s()),
                format!("{:.4}", p_t.median_s()),
                format!("{speedup:.2}x"),
            ]);
            cases.push(Case {
                n,
                workers: w,
                inproc_s: in_t.median_s(),
                proc_s: p_t.median_s(),
                speedup,
            });
        }
    }
    println!();
    table.print();
    table.save("bench_dist_scaling").ok();
    write_json(&cases).expect("write BENCH_dist.json");
    println!(
        "\nwrote results/BENCH_dist.json — expect speedup ≥ 1 once per-shard \
         kernel work dominates the O(n·t) broadcast/gather round trip"
    );
}

/// Hand-rolled JSON (no serde offline): the schema CI archives and
/// `ci/bench_diff.py` gates against the committed baseline. `"b"` is the
/// worker count (an identity key for the differ).
fn write_json(cases: &[Case]) -> std::io::Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"dist_scaling\",\n");
    out.push_str(&format!("  \"threads\": {},\n", par::num_threads()));
    out.push_str(&format!("  \"t\": {T_COLS},\n"));
    out.push_str(&format!("  \"iters\": {ITERS},\n"));
    out.push_str("  \"cases\": [\n");
    for (i, c) in cases.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"proc_vs_inproc\", \"n\": {}, \"b\": {}, \"inproc_s\": {:.4}, \
             \"proc_s\": {:.4}, \"speedup\": {:.3}}}{}\n",
            c.n,
            c.workers,
            c.inproc_s,
            c.proc_s,
            c.speedup,
            if i + 1 < cases.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n");
    out.push_str("}\n");
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_dist.json", out)
}
