//! Bench: **in-process vs multi-process shard placement** for the
//! partitioned-kernel mBCG product loop (Wang et al. 2019 §3: broadcast
//! the skinny RHS, gather per-shard partials — O(n·t) traffic per
//! iteration regardless of worker count).
//!
//! Both placements run the identical fixed-iteration mBCG solve over the
//! identical shard partition; the only variable is where shard rows are
//! generated and contracted — the calling process's thread pool vs forked
//! `bbmm shard-worker` processes on the wire protocol. Parity is gated to
//! 1e-8 before anything is timed.
//!
//! Grid: n ∈ {32768, 131072} × workers ∈ {1, 2, 4} (quick mode:
//! n = 2048, workers ∈ {1, 2} — CI-sized, where the expectation is
//! parity-not-regression; process parallelism pays off at the full
//! sizes on multi-core hosts). Each cell is then re-run with the
//! shared-memory data plane (`shm_vs_tcp` rows): identical partition,
//! identical solve, parity-gated against the same reference, with the
//! per-round bytes actually crossing the socket reported for both
//! transports — the shm lane's payload traffic must be **zero**. Writes
//! `results/BENCH_dist.json` (the CI perf artifact; `"b"` carries the
//! worker count) plus the table/CSV pair.

use bbmm_gp::bench::{bench, Table};
use bbmm_gp::kernels::{Rbf, ShardedKernelOp};
use bbmm_gp::linalg::mbcg::{mbcg_op, MbcgOptions};
use bbmm_gp::runtime::dist::{
    MultiProcessBackend, NumaMode, ShardBackend, ShmOptions, Transport, WorkerLaunch,
};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::par;
use bbmm_gp::util::Rng;
use std::sync::Arc;

const T_COLS: usize = 8;
const ITERS: usize = 10;
const WORKER_BUDGET_MB: usize = 512;

struct Case {
    n: usize,
    workers: usize,
    inproc_s: f64,
    proc_s: f64,
    speedup: f64,
}

/// One shm-vs-TCP cell: same partition and solve, only the data plane
/// differs. `*_wire_b` is the mean payload bytes crossing the socket per
/// Matmul round (control-plane bytes excluded for both).
struct ShmCase {
    n: usize,
    workers: usize,
    tcp_s: f64,
    shm_s: f64,
    speedup: f64,
    tcp_wire_b: u64,
    shm_wire_b: u64,
}

fn main() {
    let quick = std::env::var("BBMM_BENCH_QUICK").is_ok();
    let sizes: &[usize] = if quick { &[2_048] } else { &[32_768, 131_072] };
    let worker_grid: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    let samples = if quick { 2 } else { 3 };
    let shards = par::num_threads().max(4);
    println!(
        "dist_scaling: t={T_COLS} iters={ITERS} shards={shards} threads={}\n",
        par::num_threads()
    );

    let launch = WorkerLaunch {
        exe: env!("CARGO_BIN_EXE_bbmm").into(),
        ..WorkerLaunch::default()
    };
    let opts = MbcgOptions {
        max_iters: ITERS,
        tol: 0.0,
        n_solve_only: T_COLS,
    };
    let mut cases = Vec::new();
    let mut shm_cases = Vec::new();
    let mut table = Table::new(&["n", "workers", "inproc_s", "proc_s", "speedup"]);
    let mut shm_table = Table::new(&["n", "workers", "tcp_s", "shm_s", "speedup", "wire_B/round"]);
    for &n in sizes {
        let mut rng = Rng::new(n as u64);
        let x = Mat::from_fn(n, 3, |_, _| rng.uniform_in(-1.0, 1.0));
        let b = Mat::from_fn(n, T_COLS, |_, _| rng.normal());
        let inproc = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.05, shards);
        let reference = mbcg_op(&inproc, &b, |m| m.clone(), &opts);
        let in_t = bench(&format!("mbcg/inproc/n{n}"), 1, samples, || {
            let _ = mbcg_op(&inproc, &b, |m| m.clone(), &opts);
        });
        for &w in worker_grid {
            let kernel = Rbf::new(0.5, 1.0);
            let proc = MultiProcessBackend::launch(
                x.clone(),
                &kernel,
                0.05,
                shards,
                w,
                WORKER_BUDGET_MB,
                launch.clone(),
            )
            .expect("fork shard workers");
            let routed = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.05, shards)
                .with_backend(Arc::new(proc));

            // parity gate before timing: the distributed placement must
            // reproduce the in-process solve to 1e-8 relative
            let got = mbcg_op(&routed, &b, |m| m.clone(), &opts);
            let scale = reference.solves.fro_norm().max(1.0);
            let diff = got.solves.max_abs_diff(&reference.solves) / scale;
            assert!(diff < 1e-8, "n={n} workers={w}: placement diverged {diff}");

            let p_t = bench(&format!("mbcg/proc{w}/n{n}"), 1, samples, || {
                let _ = mbcg_op(&routed, &b, |m| m.clone(), &opts);
            });
            let tcp_stats = routed.backend().unwrap().stats();
            assert_eq!(
                tcp_stats.restarts, 0,
                "n={n} workers={w}: workers crashed during the bench"
            );
            let tcp_wire_b =
                (tcp_stats.bytes_tx + tcp_stats.bytes_rx) / tcp_stats.rounds.max(1);
            drop(routed); // shuts the worker fleet down before the next config

            let speedup = in_t.median_s() / p_t.median_s();
            table.row(&[
                n.to_string(),
                w.to_string(),
                format!("{:.4}", in_t.median_s()),
                format!("{:.4}", p_t.median_s()),
                format!("{speedup:.2}x"),
            ]);
            cases.push(Case {
                n,
                workers: w,
                inproc_s: in_t.median_s(),
                proc_s: p_t.median_s(),
                speedup,
            });

            // same cell over the zero-copy data plane (degrades to TCP —
            // speedup ≈ 1 — where the segment cannot map, so the cell is
            // emitted either way and the committed floor stays meaningful)
            let kernel = Rbf::new(0.5, 1.0);
            let shm_proc = Arc::new(
                MultiProcessBackend::launch_with(
                    x.clone(),
                    &kernel,
                    0.05,
                    shards,
                    w,
                    WORKER_BUDGET_MB,
                    launch.clone(),
                    Transport::Shm(ShmOptions::default()),
                    NumaMode::Auto,
                )
                .expect("fork shard workers over shm"),
            );
            if !shm_proc.shm_active() {
                println!("  ! shm degraded: {}", shm_proc.describe());
            }
            let shm_routed =
                ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.05, shards)
                    .with_backend(shm_proc.clone() as Arc<dyn ShardBackend>);
            let got = mbcg_op(&shm_routed, &b, |m| m.clone(), &opts);
            let diff = got.solves.max_abs_diff(&reference.solves) / scale;
            assert!(diff < 1e-8, "n={n} workers={w}: shm placement diverged {diff}");
            let s_t = bench(&format!("mbcg/shm{w}/n{n}"), 1, samples, || {
                let _ = mbcg_op(&shm_routed, &b, |m| m.clone(), &opts);
            });
            let shm_stats = shm_proc.stats();
            assert_eq!(
                shm_stats.restarts, 0,
                "n={n} workers={w}: shm workers crashed during the bench"
            );
            let shm_wire_b =
                (shm_stats.bytes_tx + shm_stats.bytes_rx) / shm_stats.rounds.max(1);
            drop(shm_routed);
            drop(shm_proc);

            let shm_speedup = p_t.median_s() / s_t.median_s();
            shm_table.row(&[
                n.to_string(),
                w.to_string(),
                format!("{:.4}", p_t.median_s()),
                format!("{:.4}", s_t.median_s()),
                format!("{shm_speedup:.2}x"),
                format!("{shm_wire_b} (tcp {tcp_wire_b})"),
            ]);
            shm_cases.push(ShmCase {
                n,
                workers: w,
                tcp_s: p_t.median_s(),
                shm_s: s_t.median_s(),
                speedup: shm_speedup,
                tcp_wire_b,
                shm_wire_b,
            });
        }
    }
    println!();
    table.print();
    println!();
    shm_table.print();
    table.save("bench_dist_scaling").ok();
    shm_table.save("bench_dist_scaling_shm").ok();
    write_json(&cases, &shm_cases).expect("write BENCH_dist.json");
    println!(
        "\nwrote results/BENCH_dist.json — expect speedup ≥ 1 once per-shard \
         kernel work dominates the O(n·t) broadcast/gather round trip"
    );
}

/// Hand-rolled JSON (no serde offline): the schema CI archives and
/// `ci/bench_diff.py` gates against the committed baseline. `"b"` is the
/// worker count (an identity key for the differ); the differ gates on
/// `speedup` for both the `proc_vs_inproc` and `shm_vs_tcp` rows, while
/// the `*_wire_b` fields are informational (payload bytes per round).
fn write_json(cases: &[Case], shm_cases: &[ShmCase]) -> std::io::Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"dist_scaling\",\n");
    out.push_str(&format!("  \"threads\": {},\n", par::num_threads()));
    out.push_str(&format!("  \"t\": {T_COLS},\n"));
    out.push_str(&format!("  \"iters\": {ITERS},\n"));
    out.push_str("  \"cases\": [\n");
    for (i, c) in cases.iter().enumerate() {
        let sep = if i + 1 < cases.len() || !shm_cases.is_empty() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"name\": \"proc_vs_inproc\", \"n\": {}, \"b\": {}, \"inproc_s\": {:.4}, \
             \"proc_s\": {:.4}, \"speedup\": {:.3}}}{sep}\n",
            c.n, c.workers, c.inproc_s, c.proc_s, c.speedup,
        ));
    }
    for (i, c) in shm_cases.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"shm_vs_tcp\", \"n\": {}, \"b\": {}, \"tcp_s\": {:.4}, \
             \"shm_s\": {:.4}, \"speedup\": {:.3}, \"tcp_wire_b\": {}, \"shm_wire_b\": {}}}{}\n",
            c.n,
            c.workers,
            c.tcp_s,
            c.shm_s,
            c.speedup,
            c.tcp_wire_b,
            c.shm_wire_b,
            if i + 1 < shm_cases.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n");
    out.push_str("}\n");
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_dist.json", out)
}
