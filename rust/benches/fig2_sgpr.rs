//! Bench: Figure 2 (middle) — SGPR training iteration, BBMM vs
//! Woodbury-Cholesky (GPflow-equivalent). BBMM_BENCH_FULL=1 for paper n.

use bbmm_gp::bench::{bench_budget, Table};
use bbmm_gp::data::synthetic::generate_sized;
use bbmm_gp::gp::mll::{BbmmEngine, InferenceEngine};
use bbmm_gp::gp::{SgprCholeskyEngine, SgprOp};
use bbmm_gp::kernels::Rbf;
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::Rng;

fn main() {
    let full = std::env::var("BBMM_BENCH_FULL").is_ok();
    let sizes: &[usize] = if full {
        &[15_000, 30_000, 50_000]
    } else {
        &[2_000, 5_000, 10_000]
    };
    let m = if full { 300 } else { 150 };
    let mut table = Table::new(&["n", "m", "chol_s", "bbmm_s", "speedup"]);
    for &n in sizes {
        let ds = generate_sized("bench_sgpr", n, 8, 2);
        let y = ds.y_train.clone();
        let mut rng = Rng::new(3);
        let mut u = Mat::zeros(m, ds.dim());
        for r in 0..m {
            let src = rng.below(ds.n_train());
            u.row_mut(r).copy_from_slice(ds.x_train.row(src));
        }
        let op = SgprOp::new(ds.x_train.clone(), u, Box::new(Rbf::new(0.5, 1.0)), 0.05);
        let chol = bench_budget(&format!("sgpr/cholesky/n{n}"), 2.0, || {
            let _ = SgprCholeskyEngine.mll_and_grad_sgpr(&op, &y);
        });
        let mut engine = BbmmEngine::new(20, 10, 0, 5);
        let bbmm = bench_budget(&format!("sgpr/bbmm/n{n}"), 2.0, || {
            let _ = engine.mll_and_grad(&op, &y);
        });
        table.row(&[
            n.to_string(),
            m.to_string(),
            format!("{:.4}", chol.median_s()),
            format!("{:.4}", bbmm.median_s()),
            format!("{:.1}x", chol.median_s() / bbmm.median_s()),
        ]);
    }
    table.print();
    table.save("bench_fig2_sgpr").ok();
}
