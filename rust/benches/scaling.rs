//! Bench: §4 complexity claim — BBMM's cost per training iteration grows
//! ~O(n²) while Cholesky grows O(n³). Fits the empirical exponents.

use bbmm_gp::bench::{bench_budget, Table};
use bbmm_gp::data::synthetic::generate_sized;
use bbmm_gp::gp::mll::{BbmmEngine, CholeskyEngine, InferenceEngine};
use bbmm_gp::kernels::{DenseKernelOp, Rbf};

/// least-squares slope of log(time) against log(n)
fn fit_exponent(ns: &[usize], ts: &[f64]) -> f64 {
    let logs: Vec<(f64, f64)> = ns
        .iter()
        .zip(ts.iter())
        .map(|(&n, &t)| ((n as f64).ln(), t.ln()))
        .collect();
    let k = logs.len() as f64;
    let sx: f64 = logs.iter().map(|(x, _)| x).sum();
    let sy: f64 = logs.iter().map(|(_, y)| y).sum();
    let sxx: f64 = logs.iter().map(|(x, _)| x * x).sum();
    let sxy: f64 = logs.iter().map(|(x, y)| x * y).sum();
    (k * sxy - sx * sy) / (k * sxx - sx * sx)
}

fn main() {
    let full = std::env::var("BBMM_BENCH_FULL").is_ok();
    let sizes: Vec<usize> = if full {
        vec![512, 1024, 2048, 4096]
    } else {
        vec![256, 512, 1024, 2048]
    };
    let mut table = Table::new(&["n", "chol_s", "bbmm_s"]);
    let mut chol_ts = Vec::new();
    let mut bbmm_ts = Vec::new();
    for &n in &sizes {
        let ds = generate_sized("bench_scaling", n, 4, 7);
        let y = ds.y_train.clone();
        let op = DenseKernelOp::new(ds.x_train.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.05);
        let chol = bench_budget(&format!("scaling/cholesky/n{n}"), 2.0, || {
            let _ = CholeskyEngine.mll_and_grad(&op, &y);
        });
        let mut engine = BbmmEngine::default();
        let bbmm = bench_budget(&format!("scaling/bbmm/n{n}"), 2.0, || {
            let _ = engine.mll_and_grad(&op, &y);
        });
        chol_ts.push(chol.median_s());
        bbmm_ts.push(bbmm.median_s());
        table.row(&[
            n.to_string(),
            format!("{:.4}", chol.median_s()),
            format!("{:.4}", bbmm.median_s()),
        ]);
    }
    table.print();
    table.save("bench_scaling").ok();
    let e_chol = fit_exponent(&sizes, &chol_ts);
    let e_bbmm = fit_exponent(&sizes, &bbmm_ts);
    println!("\nfitted exponents: cholesky n^{e_chol:.2}  bbmm n^{e_bbmm:.2}");
    println!("paper claim: cholesky → 3.0, bbmm → 2.0 (plus lower-order terms)");
}
