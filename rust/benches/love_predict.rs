//! Bench: **LOVE posterior cache vs per-query solve** — the constant-time
//! predictive-variance payoff measured.
//!
//! The serving regime: a trained exact GP answers single-point
//! mean+variance queries. The baseline pays one dispatched mBCG solve per
//! query (cross build + `K̂⁻¹[y k_*ᵀ]`); the LOVE path freezes the
//! posterior once (`α = K̂⁻¹y` + rank-r Lanczos root) and answers every
//! query with two skinny GEMMs — O(n·iters·n) → O(n·r) per query.
//!
//! Parity is gated before timing: LOVE mean/variance must match the
//! solve path to 1e-5 at every probe (d=1 RBF data keeps the effective
//! spectrum well inside rank 64, so the cached root is near-exact).
//!
//! Grid: n ∈ {2k, 8k}. Writes `results/BENCH_love.json` (the CI
//! perf artifact) plus the usual table/CSV pair. `BBMM_BENCH_QUICK=1`
//! cuts per-case samples, not the grid.

use bbmm_gp::bench::{bench, Table};
use bbmm_gp::gp::LovePosterior;
use bbmm_gp::kernels::{Kernel, KernelCovOp, Rbf};
use bbmm_gp::linalg::op::{solve, AddedDiagOp, SolveOptions};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::par;
use bbmm_gp::util::Rng;
use std::time::Instant;

const RANK: usize = 64;
const SOLVE_QUERIES: usize = 2;
const LOVE_QUERIES: usize = 64;

struct Case {
    n: usize,
    solve_query_s: f64,
    love_query_s: f64,
    build_s: f64,
    speedup: f64,
}

fn cross_row(kernel: &dyn Kernel, x: &Mat, xt: f64) -> Mat {
    Mat::from_fn(1, x.rows(), |_, j| kernel.eval(&[xt], x.row(j)))
}

fn main() {
    let quick = std::env::var("BBMM_BENCH_QUICK").is_ok();
    let samples = if quick { 2 } else { 3 };
    let sizes = [2_000usize, 8_000];
    println!(
        "love_predict: rank={RANK} samples={samples} threads={}\n",
        par::num_threads()
    );

    let opts = SolveOptions {
        max_iters: 50,
        tol: 1e-8,
        precond_rank: 5,
    };
    let mut cases = Vec::new();
    let mut table = Table::new(&["n", "solve_query_s", "love_query_s", "build_s", "speedup"]);
    for &n in &sizes {
        let mut rng = Rng::new(n as u64);
        let mut x_raw: Vec<f64> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        x_raw.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let x = Mat::from_vec(n, 1, x_raw);
        let y: Vec<f64> = (0..n).map(|i| (4.0 * x.get(i, 0)).sin() + 0.05 * rng.normal()).collect();
        let kernel = Rbf::new(0.4, 1.0);
        let cov = KernelCovOp::new(x.clone(), Box::new(Rbf::new(0.4, 1.0)));
        let op = AddedDiagOp::new(cov, 0.05);
        let probes: Vec<f64> = (0..LOVE_QUERIES).map(|_| rng.uniform_in(-0.9, 0.9)).collect();

        // freeze the posterior once — this is the cost LOVE amortises
        let t0 = Instant::now();
        let post = LovePosterior::build(&op, &y, RANK, &opts);
        let build_s = t0.elapsed().as_secs_f64();

        // parity gate before timing: cached-root answers must match the
        // per-query solve path
        for &xt in probes.iter().take(4) {
            let k_star = cross_row(&kernel, &x, xt);
            let kss = kernel.eval(&[xt], &[xt]);
            let love = post.predict(&k_star, &[kss]);
            let reference =
                bbmm_gp::gp::predict::predict(&k_star, &[kss], |m| solve(&op, m, &opts), &y);
            let dm = (love.mean[0] - reference.mean[0]).abs();
            let dv = (love.var[0] - reference.var[0]).abs() / reference.var[0].abs().max(1e-9);
            assert!(dm < 1e-5, "n={n} x={xt}: mean diverged {dm}");
            assert!(dv < 1e-5, "n={n} x={xt}: var diverged {dv}");
        }

        let solved = bench(&format!("predict/solve/n{n}"), 1, samples, || {
            for &xt in probes.iter().take(SOLVE_QUERIES) {
                let k_star = cross_row(&kernel, &x, xt);
                let kss = kernel.eval(&[xt], &[xt]);
                let _ = bbmm_gp::gp::predict::predict(
                    &k_star,
                    &[kss],
                    |m| solve(&op, m, &opts),
                    &y,
                );
            }
        });
        let loved = bench(&format!("predict/love/n{n}"), 1, samples, || {
            for &xt in &probes {
                let k_star = cross_row(&kernel, &x, xt);
                let kss = kernel.eval(&[xt], &[xt]);
                let _ = post.predict(&k_star, &[kss]);
            }
        });
        let solve_query_s = solved.median_s() / SOLVE_QUERIES as f64;
        let love_query_s = loved.median_s() / LOVE_QUERIES as f64;
        let speedup = solve_query_s / love_query_s;
        table.row(&[
            n.to_string(),
            format!("{solve_query_s:.5}"),
            format!("{love_query_s:.6}"),
            format!("{build_s:.3}"),
            format!("{speedup:.1}x"),
        ]);
        cases.push(Case {
            n,
            solve_query_s,
            love_query_s,
            build_s,
            speedup,
        });
    }
    println!();
    table.print();
    table.save("bench_love_predict").ok();
    write_json(&cases).expect("write BENCH_love.json");
    println!(
        "\nwrote results/BENCH_love.json — expect speedup to grow with n \
         (per-query solve pays O(n·iters·n); the cached root pays O(n·r))"
    );
}

/// Hand-rolled JSON (no serde offline): the schema CI archives and
/// `ci/bench_diff.py` gates against the committed baseline.
fn write_json(cases: &[Case]) -> std::io::Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"love_predict\",\n");
    out.push_str(&format!("  \"threads\": {},\n", par::num_threads()));
    out.push_str(&format!("  \"rank\": {RANK},\n"));
    out.push_str("  \"cases\": [\n");
    for (i, c) in cases.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"n\": {}, \"rank\": {}, \"solve_query_s\": {:.6}, \"love_query_s\": {:.8}, \
             \"build_s\": {:.4}, \"speedup\": {:.3}}}{}\n",
            c.n,
            RANK,
            c.solve_query_s,
            c.love_query_s,
            c.build_s,
            c.speedup,
            if i + 1 < cases.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n");
    out.push_str("}\n");
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_love.json", out)
}
