//! Bench: **the iteration-amortised kernel MMM engine measured** — raw
//! GEMM FLOP rate plus materialisation-plan vs streaming solve wall-clock.
//!
//! Two sections, both written to `results/BENCH_mmm.json` (the CI perf
//! artifact, diffed non-blocking against the committed baseline):
//!
//! 1. **GEMM GFLOP/s** — square `Mat::matmul` at a few sizes; the
//!    register-blocked micro-kernel's first real FLOP-rate number.
//! 2. **Plan vs stream** — a full stationary mBCG solve (fixed iteration
//!    budget, tol 0) at n ∈ {2k, 8k}, t ∈ {8, 32}, run under each
//!    [`MmmPlan`]: `Stream` (rebuild every kernel row per product),
//!    `CachedDistances` (one r² panel), `MaterializeK` (one K panel, every
//!    product a GEMM). Solves are parity-gated to 1e-10 relative before
//!    timing, so the speedup column never reports a wrong answer faster.
//!
//! 3. **SIMD × precision GEMM cells** — the same contraction per
//!    (precision, dispatch) cell: f64/f32/mixed under the forced-scalar
//!    portable path and under the runtime-detected SIMD path, reporting
//!    GFLOP/s, the SIMD-over-scalar speedup, and the fraction of the
//!    ideal lane-width speedup achieved (the roofline fraction — explicit
//!    lanes can't beat `lanes×` over an autovectorised scalar loop, so
//!    `speedup/lanes` is the honest efficiency number).
//! 4. **Mixed-precision solve cells** — the Stream/CachedDistances solves
//!    of section 2 re-run under [`Precision::Mixed`] (f32 tiles, f64
//!    reductions), parity-gated at 1e-3 relative against the f64 solve
//!    before timing.
//!
//! `BBMM_BENCH_QUICK=1` (CI) keeps the grid but cuts the iteration budget
//! and samples; the full run uses the acceptance configuration
//! (50 iterations).

use bbmm_gp::bench::{bench, Table};
use bbmm_gp::kernels::{KernelCovOp, Rbf};
use bbmm_gp::linalg::mbcg::{mbcg, MbcgOptions};
use bbmm_gp::linalg::op::{AddedDiagOp, LinearOp, MmmPlan, Precision};
use bbmm_gp::tensor::{gemm, simd, Mat};
use bbmm_gp::util::par;
use bbmm_gp::util::Rng;

struct GemmCase {
    n: usize,
    gflops: f64,
}

struct SimdCase {
    name: &'static str,
    dispatch: &'static str,
    n: usize,
    gflops: f64,
    scalar_speedup: f64,
    roofline_frac: f64,
}

struct SolveCase {
    n: usize,
    t: usize,
    iters: usize,
    stream_s: f64,
    cached_s: f64,
    materialize_s: f64,
}

struct MixedSolveCase {
    name: &'static str,
    n: usize,
    t: usize,
    f64_s: f64,
    mixed_s: f64,
}

fn main() {
    let quick = std::env::var("BBMM_BENCH_QUICK").is_ok();
    let samples = if quick { 2 } else { 3 };
    let solve_iters = if quick { 5 } else { 50 };
    println!(
        "mmm_microbench: threads={} quick={quick} solve_iters={solve_iters}\n",
        par::num_threads()
    );

    // ---- 1) raw GEMM FLOP rate ----
    let mut gemm_cases = Vec::new();
    let mut gtable = Table::new(&["n", "median_s", "gflops"]);
    for &n in &[256usize, 512, 1024] {
        let mut rng = Rng::new(n as u64);
        let a = Mat::from_fn(n, n, |_, _| rng.normal());
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut out = Mat::zeros(n, n);
        let res = bench(&format!("gemm/n{n}"), 1, samples, || {
            a.matmul_into(&b, &mut out);
        });
        let flops = 2.0 * (n as f64).powi(3);
        let gflops = flops / res.median_s() / 1e9;
        gtable.row(&[n.to_string(), format!("{:.4}", res.median_s()), format!("{gflops:.2}")]);
        gemm_cases.push(GemmCase { n, gflops });
    }
    println!();
    gtable.print();

    // ---- 1b) SIMD dispatch × precision GEMM cells ----
    // One contraction shape, each precision timed twice: dispatcher pinned
    // to the portable scalar path, then the runtime-detected SIMD path
    // (identical timings when no SIMD arm exists for this target).
    let mut simd_cases = Vec::new();
    {
        let n = 512usize;
        let flops = 2.0 * (n as f64).powi(3);
        let mut rng = Rng::new(512);
        let a = Mat::from_fn(n, n, |_, _| rng.normal());
        let b = Mat::from_fn(n, n, |_, _| rng.normal());
        let a32 = a.cast::<f32>();
        let b32 = b.cast::<f32>();
        let mut out = Mat::zeros(n, n);
        let mut out32 = Mat::<f32>::zeros(n, n);

        simd::set_forced_scalar(true);
        let r = bench("gemm_f64/scalar", 1, samples, || {
            out.data_mut().fill(0.0);
            gemm::gemm_into(a.data(), b.data(), out.data_mut(), n, n, n);
        });
        let sc_f64 = flops / r.median_s() / 1e9;
        let r = bench("gemm_f32/scalar", 1, samples, || {
            out32.data_mut().fill(0.0);
            gemm::gemm_into(a32.data(), b32.data(), out32.data_mut(), n, n, n);
        });
        let sc_f32 = flops / r.median_s() / 1e9;
        let r = bench("gemm_mixed/scalar", 1, samples, || {
            out.data_mut().fill(0.0);
            gemm::gemm_mixed_into(a32.data(), b32.data(), out.data_mut(), n, n, n);
        });
        let sc_mixed = flops / r.median_s() / 1e9;
        simd::set_forced_scalar(false);

        let d = simd::active();
        let r = bench(&format!("gemm_f64/{}", d.name()), 1, samples, || {
            out.data_mut().fill(0.0);
            gemm::gemm_into(a.data(), b.data(), out.data_mut(), n, n, n);
        });
        let v_f64 = flops / r.median_s() / 1e9;
        let r = bench(&format!("gemm_f32/{}", d.name()), 1, samples, || {
            out32.data_mut().fill(0.0);
            gemm::gemm_into(a32.data(), b32.data(), out32.data_mut(), n, n, n);
        });
        let v_f32 = flops / r.median_s() / 1e9;
        let r = bench(&format!("gemm_mixed/{}", d.name()), 1, samples, || {
            out.data_mut().fill(0.0);
            gemm::gemm_mixed_into(a32.data(), b32.data(), out.data_mut(), n, n, n);
        });
        let v_mixed = flops / r.median_s() / 1e9;

        for (name, v, sc, lanes) in [
            ("gemm_f64", v_f64, sc_f64, d.lanes_f64()),
            ("gemm_f32", v_f32, sc_f32, d.lanes_f32()),
            ("gemm_mixed", v_mixed, sc_mixed, d.lanes_f32()),
        ] {
            simd_cases.push(SimdCase {
                name,
                dispatch: d.name(),
                n,
                gflops: v,
                scalar_speedup: v / sc,
                roofline_frac: (v / sc) / lanes as f64,
            });
        }
        println!();
        let mut ttable =
            Table::new(&["cell", "dispatch", "gflops", "speedup_vs_scalar", "roofline_frac"]);
        for c in &simd_cases {
            ttable.row(&[
                c.name.to_string(),
                c.dispatch.to_string(),
                format!("{:.2}", c.gflops),
                format!("{:.2}x", c.scalar_speedup),
                format!("{:.2}", c.roofline_frac),
            ]);
        }
        ttable.print();
    }

    // ---- 2) materialisation plans vs streaming on a full mBCG solve ----
    let mut solve_cases = Vec::new();
    let mut mixed_cases: Vec<MixedSolveCase> = Vec::new();
    let mut stable = Table::new(&["n", "t", "stream_s", "cached_s", "matk_s", "best_speedup"]);
    for &n in &[2_000usize, 8_000] {
        let mut rng = Rng::new(100 + n as u64);
        let x = Mat::from_fn(n, 4, |_, _| rng.uniform_in(-1.0, 1.0));
        for &t in &[8usize, 32] {
            let rhs = Mat::from_fn(n, t, |_, _| rng.normal());
            // scalar mbcg asserts n_solve_only <= cols (usize::MAX is the
            // batched path's clamp-per-system convention only)
            let opts = MbcgOptions {
                max_iters: solve_iters,
                tol: 0.0,
                n_solve_only: t,
            };
            let plans = [MmmPlan::Stream, MmmPlan::CachedDistances, MmmPlan::MaterializeK];
            let mut times = [0.0f64; 3];
            let mut solves: Vec<Mat> = Vec::new();
            for (pi, &plan) in plans.iter().enumerate() {
                let cov = KernelCovOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)))
                    .with_plan(plan);
                let op = AddedDiagOp::new(cov, 0.1);
                op.prepare(); // panel builds are per-solve setup, not loop cost
                let res = bench(
                    &format!("solve/{}/n{n}/t{t}", plan.name()),
                    1,
                    samples,
                    || {
                        let _ = mbcg(|m| op.matmul(m), &rhs, |m| m.clone(), &opts);
                    },
                );
                times[pi] = res.median_s();
                solves.push(mbcg(|m| op.matmul(m), &rhs, |m| m.clone(), &opts).solves);
            }
            // parity gate: every plan must produce the same solve
            let scale = solves[0].fro_norm().max(1.0);
            for (pi, s) in solves.iter().enumerate().skip(1) {
                let diff = s.max_abs_diff(&solves[0]) / scale;
                assert!(
                    diff < 1e-10,
                    "plan {} diverged from stream at n={n} t={t}: rel diff {diff}",
                    plans[pi].name()
                );
            }
            let best = times[0] / times[1].min(times[2]);
            stable.row(&[
                n.to_string(),
                t.to_string(),
                format!("{:.4}", times[0]),
                format!("{:.4}", times[1]),
                format!("{:.4}", times[2]),
                format!("{best:.2}x"),
            ]);
            solve_cases.push(SolveCase {
                n,
                t,
                iters: solve_iters,
                stream_s: times[0],
                cached_s: times[1],
                materialize_s: times[2],
            });
            // ---- 4) mixed-precision full-solve cells ----
            // Stream + CachedDistances re-run under f32 tiles / f64
            // reductions; parity-gated against the f64 solve BEFORE
            // timing, so the speedup column never reports a wrong answer
            // faster (gate 1e-3: f32 tile rounding through the solve).
            for (pi, &(plan, pname)) in
                [(MmmPlan::Stream, "stream"), (MmmPlan::CachedDistances, "cached-r2")]
                    .iter()
                    .enumerate()
            {
                let cov = KernelCovOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)))
                    .with_plan(plan)
                    .with_precision(Precision::Mixed);
                let op = AddedDiagOp::new(cov, 0.1);
                op.prepare();
                let got = mbcg(|m| op.matmul(m), &rhs, |m| m.clone(), &opts).solves;
                let diff = got.max_abs_diff(&solves[pi]) / scale;
                assert!(
                    diff < 1e-3,
                    "mixed {pname} diverged from f64 at n={n} t={t}: rel diff {diff}"
                );
                let res = bench(&format!("solve/mixed-{pname}/n{n}/t{t}"), 1, samples, || {
                    let _ = mbcg(|m| op.matmul(m), &rhs, |m| m.clone(), &opts);
                });
                mixed_cases.push(MixedSolveCase {
                    name: pname,
                    n,
                    t,
                    f64_s: times[pi],
                    mixed_s: res.median_s(),
                });
            }
        }
    }
    println!();
    stable.print();
    println!();
    let mut mtable = Table::new(&["plan", "n", "t", "f64_s", "mixed_s", "mixed_speedup"]);
    for c in &mixed_cases {
        mtable.row(&[
            c.name.to_string(),
            c.n.to_string(),
            c.t.to_string(),
            format!("{:.4}", c.f64_s),
            format!("{:.4}", c.mixed_s),
            format!("{:.2}x", c.f64_s / c.mixed_s),
        ]);
    }
    mtable.print();
    stable.save("bench_mmm").ok();
    write_json(&gemm_cases, &simd_cases, &solve_cases, &mixed_cases)
        .expect("write BENCH_mmm.json");
    println!(
        "\nwrote results/BENCH_mmm.json — expect cached-r2/materialize-k ≥ 2x over \
         stream on the full-iteration solve (the panel amortises across every \
         mBCG product; at 50 iterations the distance+exp work is paid once, not \
         50x), SIMD f64 GEMM ≥ 2x the forced-scalar rate, and mixed ≥ 1.5x the \
         f64 stream/cached-r2 solves (f32 tiles at twice the lane width, f64 \
         reductions — parity-gated above)"
    );
}

/// Hand-rolled JSON (no serde offline): the schema CI archives and diffs
/// against `benches/BENCH_mmm_baseline.json`.
///
/// Solve iteration counts are written as `solve_iters` on purpose:
/// `iters` is one of `ci/bench_diff.py`'s case-identity keys, and the CI
/// quick run uses a different budget than the full run — encoding it in
/// the identity would make every baseline case "missing" on one of them.
fn write_json(
    gemm: &[GemmCase],
    simd_cells: &[SimdCase],
    solves: &[SolveCase],
    mixed: &[MixedSolveCase],
) -> std::io::Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"mmm_microbench\",\n");
    out.push_str(&format!("  \"threads\": {},\n", par::num_threads()));
    out.push_str(&format!("  \"dispatch\": \"{}\",\n", simd::active().name()));
    out.push_str("  \"gemm\": [\n");
    for (i, c) in gemm.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"n\": {}, \"gflops\": {:.3}}}{}\n",
            c.n,
            c.gflops,
            if i + 1 < gemm.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"simd\": [\n");
    for (i, c) in simd_cells.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"dispatch\": \"{}\", \"n\": {}, \
             \"gflops\": {:.3}, \"scalar_speedup\": {:.3}, \
             \"roofline_frac\": {:.3}}}{}\n",
            c.name,
            c.dispatch,
            c.n,
            c.gflops,
            c.scalar_speedup,
            c.roofline_frac,
            if i + 1 < simd_cells.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"solves\": [\n");
    for (i, c) in solves.iter().enumerate() {
        let cached_speedup = c.stream_s / c.cached_s;
        let matk_speedup = c.stream_s / c.materialize_s;
        out.push_str(&format!(
            "    {{\"n\": {}, \"t\": {}, \"solve_iters\": {}, \"stream_s\": {:.6}, \
             \"cached_s\": {:.6}, \"materialize_s\": {:.6}, \
             \"cached_speedup\": {:.3}, \"materialize_speedup\": {:.3}}}{}\n",
            c.n,
            c.t,
            c.iters,
            c.stream_s,
            c.cached_s,
            c.materialize_s,
            cached_speedup,
            matk_speedup,
            if i + 1 < solves.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    out.push_str("  \"mixed_solves\": [\n");
    for (i, c) in mixed.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"n\": {}, \"t\": {}, \"f64_s\": {:.6}, \
             \"mixed_s\": {:.6}, \"mixed_speedup\": {:.3}}}{}\n",
            c.name,
            c.n,
            c.t,
            c.f64_s,
            c.mixed_s,
            c.f64_s / c.mixed_s,
            if i + 1 < mixed.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n");
    out.push_str("}\n");
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_mmm.json", out)
}
