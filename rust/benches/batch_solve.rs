//! Bench: **batched vs looped solve** — the batch-axis payoff measured.
//!
//! b systems share one covariance `K` with per-system σ² (the shared
//! `BatchOp` fast path: hyperparameter sweeps, per-tenant noise fleets).
//! The looped baseline runs b independent mBCG solves — b kernel-row
//! generations per iteration; the batched path runs `mbcg_batch` — **one**
//! fused `K·[D₁ … D_b]` per iteration. Identical iteration counts and
//! numerics (fixed budget, tol 0, identity preconditioner), so the gap is
//! purely the amortised operator work.
//!
//! Grid: n ∈ {2k, 8k}, b ∈ {1, 4, 16}. Writes
//! `results/BENCH_batch.json` (the CI perf artifact) plus the usual
//! table/CSV pair. `BBMM_BENCH_QUICK=1` cuts per-case samples, not the
//! grid, so the artifact schema is stable across environments.

use bbmm_gp::bench::{bench, Table};
use bbmm_gp::kernels::{KernelCovOp, Rbf};
use bbmm_gp::linalg::mbcg::{mbcg, mbcg_batch, MbcgOptions};
use bbmm_gp::linalg::op::{AddedDiagOp, BatchOp, LinearOp};
use bbmm_gp::linalg::preconditioner::{IdentityPrecond, Preconditioner};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::par;
use bbmm_gp::util::Rng;

const ITERS: usize = 5;
const RHS_COLS: usize = 1;

struct Case {
    n: usize,
    b: usize,
    looped_s: f64,
    batched_s: f64,
}

fn main() {
    let quick = std::env::var("BBMM_BENCH_QUICK").is_ok();
    let samples = if quick { 2 } else { 3 };
    let sizes = [2_000usize, 8_000];
    let batches = [1usize, 4, 16];
    println!(
        "batch_solve: iters={ITERS} rhs_cols={RHS_COLS} samples={samples} threads={}\n",
        par::num_threads()
    );

    let opts = MbcgOptions {
        max_iters: ITERS,
        tol: 0.0,
        n_solve_only: RHS_COLS,
    };
    let mut cases = Vec::new();
    let mut table = Table::new(&["n", "b", "looped_s", "batched_s", "speedup"]);
    for &n in &sizes {
        let mut rng = Rng::new(n as u64);
        let x = Mat::from_fn(n, 4, |_, _| rng.uniform_in(-1.0, 1.0));
        let cov = KernelCovOp::new(x, Box::new(Rbf::new(0.5, 1.0)));
        let sigma2s: Vec<f64> = (0..16).map(|i| 0.05 * (1.0 + 0.1 * i as f64)).collect();
        let bs: Vec<Mat> = (0..16)
            .map(|_| Mat::from_fn(n, RHS_COLS, |_, _| rng.normal()))
            .collect();
        for &b in &batches {
            let batch = BatchOp::shared(&cov, sigma2s[..b].to_vec());
            let elements: Vec<AddedDiagOp<&KernelCovOp>> = sigma2s[..b]
                .iter()
                .map(|&s2| AddedDiagOp::new(&cov, s2))
                .collect();
            let b_refs: Vec<&Mat> = bs[..b].iter().collect();
            let id = IdentityPrecond;
            let preconds: Vec<&dyn Preconditioner> =
                (0..b).map(|_| &id as &dyn Preconditioner).collect();

            // correctness gate before timing: batched == looped
            {
                let batched = mbcg_batch(&batch, &b_refs, &preconds, &opts);
                for (k, res) in batched.iter().enumerate() {
                    let mono = mbcg(|m| elements[k].matmul(m), &bs[k], |m| m.clone(), &opts);
                    let diff = res.solves.max_abs_diff(&mono.solves);
                    assert!(diff < 1e-10, "n={n} b={b} system {k} diverged: {diff}");
                }
            }

            let looped = bench(&format!("solve/looped/n{n}/b{b}"), 1, samples, || {
                for k in 0..b {
                    let _ = mbcg(|m| elements[k].matmul(m), &bs[k], |m| m.clone(), &opts);
                }
            });
            let batched = bench(&format!("solve/batched/n{n}/b{b}"), 1, samples, || {
                let _ = mbcg_batch(&batch, &b_refs, &preconds, &opts);
            });
            let (ls, bsed) = (looped.median_s(), batched.median_s());
            table.row(&[
                n.to_string(),
                b.to_string(),
                format!("{ls:.4}"),
                format!("{bsed:.4}"),
                format!("{:.2}x", ls / bsed),
            ]);
            cases.push(Case {
                n,
                b,
                looped_s: ls,
                batched_s: bsed,
            });
        }
    }
    println!();
    table.print();
    table.save("bench_batch_solve").ok();
    write_json(&cases).expect("write BENCH_batch.json");
    println!(
        "\nwrote results/BENCH_batch.json — expect batched ≥ looped as b grows \
         (kernel-row generation amortised across the batch)"
    );
}

/// Hand-rolled JSON (no serde offline): the schema CI archives as the
/// perf-trajectory artifact.
fn write_json(cases: &[Case]) -> std::io::Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"batch_solve\",\n");
    out.push_str(&format!("  \"threads\": {},\n", par::num_threads()));
    out.push_str(&format!("  \"iters\": {ITERS},\n"));
    out.push_str(&format!("  \"rhs_cols\": {RHS_COLS},\n"));
    out.push_str("  \"cases\": [\n");
    for (i, c) in cases.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"n\": {}, \"b\": {}, \"looped_s\": {:.6}, \"batched_s\": {:.6}, \
             \"speedup\": {:.3}}}{}\n",
            c.n,
            c.b,
            c.looped_s,
            c.batched_s,
            c.looped_s / c.batched_s,
            if i + 1 < cases.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n");
    out.push_str("}\n");
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_batch.json", out)
}
