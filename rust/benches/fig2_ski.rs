//! Bench: Figure 2 (right) — SKI+DKL training iteration, BBMM vs the
//! sequential Dong et al. engine. BBMM_BENCH_FULL=1 for paper-scale n.

use bbmm_gp::bench::{bench_budget, Table};
use bbmm_gp::data::synthetic::generate_sized;
use bbmm_gp::gp::mll::{BbmmEngine, InferenceEngine};
use bbmm_gp::gp::{DongEngine, SkiOp};
use bbmm_gp::kernels::{DeepFeatureMap, Rbf};
use bbmm_gp::util::Rng;

fn main() {
    let full = std::env::var("BBMM_BENCH_FULL").is_ok();
    let sizes: &[usize] = if full {
        &[50_000, 150_000, 500_000]
    } else {
        &[10_000, 30_000, 60_000]
    };
    let grid_m = if full { 10_000 } else { 2_000 };
    let mut table = Table::new(&["n", "grid_m", "dong_s", "bbmm_s", "speedup"]);
    for &n in sizes {
        let ds = generate_sized("bench_ski", n, 8, 4);
        let y = ds.y_train.clone();
        let mut rng = Rng::new(5);
        let dkl = DeepFeatureMap::new(&[ds.dim(), 32, 8, 1], &mut rng);
        let feat = dkl.forward(&ds.x_train);
        let z: Vec<f64> = (0..ds.n_train()).map(|i| feat.get(i, 0)).collect();
        let op = SkiOp::new(z, grid_m, Box::new(Rbf::new(0.3, 1.0)), 0.05);
        let mut dong = DongEngine::new(20, 10, 6);
        let dong_r = bench_budget(&format!("ski/dong/n{n}"), 2.0, || {
            let _ = dong.mll_and_grad(&op, &y);
        });
        let mut bbmm = BbmmEngine::new(20, 10, 0, 6);
        let bbmm_r = bench_budget(&format!("ski/bbmm/n{n}"), 2.0, || {
            let _ = bbmm.mll_and_grad(&op, &y);
        });
        table.row(&[
            n.to_string(),
            grid_m.to_string(),
            format!("{:.4}", dong_r.median_s()),
            format!("{:.4}", bbmm_r.median_s()),
            format!("{:.1}x", dong_r.median_s() / bbmm_r.median_s()),
        ]);
    }
    table.print();
    table.save("bench_fig2_ski").ok();
}
