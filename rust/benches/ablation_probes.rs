//! Bench/ablation A2: accuracy of the stochastic log-det and trace
//! estimators as a function of probe count t and CG iterations p
//! (the paper's §6 defaults are t=10, p=20 — this shows why they suffice).

use bbmm_gp::bench::Table;
use bbmm_gp::gp::mll::{BbmmEngine, CholeskyEngine, InferenceEngine};
use bbmm_gp::kernels::{DenseKernelOp, Rbf};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::Rng;

fn main() {
    let n = 400;
    let mut rng = Rng::new(11);
    let x = Mat::from_fn(n, 3, |_, _| rng.uniform_in(-1.0, 1.0));
    let y: Vec<f64> = (0..n).map(|i| (3.0 * x.get(i, 0)).sin() + 0.05 * rng.normal()).collect();
    let op = DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), 0.05);
    let exact = CholeskyEngine.mll_and_grad(&op, &y);
    println!("exact: logdet {:.4}  grad {:?}\n", exact.logdet, exact.grad);

    // sweep probes at fixed p
    let mut t_table = Table::new(&["t_probes", "logdet_rel_err", "grad_ls_rel_err"]);
    for &t in &[2usize, 5, 10, 20, 50, 100] {
        let (mut lg, mut gr) = (0.0, 0.0);
        let reps = 5;
        for rep in 0..reps {
            let mut e = BbmmEngine::new(40, t, 5, 100 + rep);
            let r = e.mll_and_grad(&op, &y);
            lg += ((r.logdet - exact.logdet) / exact.logdet).abs();
            gr += ((r.grad[0] - exact.grad[0]) / exact.grad[0].abs().max(1.0)).abs();
        }
        t_table.row(&[
            t.to_string(),
            format!("{:.4}", lg / reps as f64),
            format!("{:.4}", gr / reps as f64),
        ]);
    }
    println!("--- error vs probe count (p=40, rank-5 precond) ---");
    t_table.print();
    t_table.save("ablation_probes_t").ok();

    // sweep CG iterations at fixed t
    let mut p_table = Table::new(&["p_iters", "logdet_rel_err", "datafit_rel_err"]);
    for &p in &[2usize, 5, 10, 20, 40, 80] {
        let (mut lg, mut df) = (0.0, 0.0);
        let reps = 5;
        for rep in 0..reps {
            let mut e = BbmmEngine::new(p, 10, 5, 200 + rep);
            e.cg_tol = 0.0; // force exactly p iterations
            let r = e.mll_and_grad(&op, &y);
            lg += ((r.logdet - exact.logdet) / exact.logdet).abs();
            df += ((r.datafit - exact.datafit) / exact.datafit).abs();
        }
        p_table.row(&[
            p.to_string(),
            format!("{:.4}", lg / reps as f64),
            format!("{:.2e}", df / reps as f64),
        ]);
    }
    println!("\n--- error vs CG iterations (t=10, rank-5 precond) ---");
    p_table.print();
    p_table.save("ablation_probes_p").ok();
    println!("\npaper shape check: datafit error collapses with p; logdet error ~1/√t");
}
