//! Cross-engine integration tests: the three inference engines (BBMM,
//! Cholesky, Dong) must agree on shared problems, across all three model
//! families, and full train→predict loops must work end to end.

use bbmm_gp::data::synthetic::generate_sized;
use bbmm_gp::gp::exact::{Engine, ExactGp};
use bbmm_gp::gp::mll::{BbmmEngine, CholeskyEngine, InferenceEngine};
use bbmm_gp::gp::predict::{mae, predict};
use bbmm_gp::gp::{DongEngine, SgprCholeskyEngine, SgprOp, SkiOp};
use bbmm_gp::kernels::{DeepFeatureMap, DenseKernelOp, Matern52, Rbf};
use bbmm_gp::linalg::op::LinearOp;
use bbmm_gp::tensor::Mat;
use bbmm_gp::train::{TrainConfig, Trainer};
use bbmm_gp::util::Rng;

#[test]
fn all_three_engines_agree_on_exact_gp() {
    let ds = generate_sized("engines", 150, 3, 1);
    let y = ds.y_train.clone();
    let op = DenseKernelOp::new(ds.x_train.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.05);
    let exact = CholeskyEngine.mll_and_grad(&op, &y);
    let mut bbmm = BbmmEngine::new(135, 64, 5, 2);
    let b = bbmm.mll_and_grad(&op, &y);
    let mut dong = DongEngine::new(135, 64, 2);
    let d = dong.mll_and_grad(&op, &y);
    for (name, r) in [("bbmm", &b), ("dong", &d)] {
        assert!(
            (r.datafit - exact.datafit).abs() / exact.datafit.abs() < 1e-4,
            "{name} datafit {} vs {}",
            r.datafit,
            exact.datafit
        );
        assert!(
            (r.logdet - exact.logdet).abs() / exact.logdet.abs().max(1.0) < 0.15,
            "{name} logdet {} vs {}",
            r.logdet,
            exact.logdet
        );
        for p in 0..op.n_params() {
            assert!(
                (r.grad[p] - exact.grad[p]).abs() < 0.25 * (1.0 + exact.grad[p].abs()),
                "{name} grad[{p}] {} vs {}",
                r.grad[p],
                exact.grad[p]
            );
        }
    }
}

#[test]
fn bbmm_sgpr_matches_woodbury_cholesky_sgpr() {
    let ds = generate_sized("sgpr_int", 400, 4, 2);
    let y = ds.y_train.clone();
    let mut rng = Rng::new(3);
    let mut u = Mat::zeros(40, ds.dim());
    for r in 0..40 {
        let src = rng.below(ds.n_train());
        u.row_mut(r).copy_from_slice(ds.x_train.row(src));
    }
    let op = SgprOp::new(ds.x_train.clone(), u, Box::new(Matern52::new(0.5, 1.0)), 0.1);
    let exact = SgprCholeskyEngine.mll_and_grad_sgpr(&op, &y);
    let mut bbmm = BbmmEngine::new(400, 64, 0, 4);
    let est = bbmm.mll_and_grad(&op, &y);
    assert!(
        (est.datafit - exact.datafit).abs() / exact.datafit.abs() < 1e-4,
        "datafit {} vs {}",
        est.datafit,
        exact.datafit
    );
    assert!(
        (est.logdet - exact.logdet).abs() / exact.logdet.abs().max(1.0) < 0.15,
        "logdet {} vs {}",
        est.logdet,
        exact.logdet
    );
}

#[test]
fn ski_deep_kernel_pipeline_trains_and_predicts() {
    // DKL features → SKI operator → BBMM training → prediction beats mean
    let ds = generate_sized("ski_int", 3000, 5, 5);
    let y = ds.y_train.clone();
    let mut rng = Rng::new(6);
    let dkl = DeepFeatureMap::new(&[ds.dim(), 16, 1], &mut rng);
    let feat = dkl.forward(&ds.x_train);
    let z: Vec<f64> = (0..ds.n_train()).map(|i| feat.get(i, 0)).collect();
    let mut op = SkiOp::new(z, 500, Box::new(Rbf::new(0.3, 1.0)), 0.1);
    let mut params = op.params();
    let mut engine = BbmmEngine::new(20, 10, 0, 7);
    let mut trainer = Trainer::new(TrainConfig {
        iters: 15,
        lr: 0.1,
        ..Default::default()
    });
    let first_nmll = {
        let mut e = BbmmEngine::new(20, 10, 0, 7);
        e.mll_and_grad(&op, &y).nmll
    };
    let best = trainer.run(&mut params, |raw| {
        op.set_params(raw);
        engine.mll_and_grad(&op, &y)
    });
    assert!(best < first_nmll, "training must improve nmll: {first_nmll} -> {best}");

    op.set_params(&params);
    let feat_test = dkl.forward(&ds.x_test);
    let z_test: Vec<f64> = (0..ds.y_test.len()).map(|i| feat_test.get(i, 0)).collect();
    let k_star = op.cross(&z_test);
    let solves = bbmm_gp::linalg::mbcg::mbcg(
        |m| op.matmul(m),
        &Mat::col_from_slice(&y),
        |m| m.clone(),
        &bbmm_gp::linalg::mbcg::MbcgOptions {
            max_iters: 100,
            tol: 1e-9,
            n_solve_only: 1,
        },
    )
    .solves;
    let alpha = solves.col(0);
    let mean: Vec<f64> = (0..z_test.len())
        .map(|i| k_star.row(i).iter().zip(alpha.iter()).map(|(a, b)| a * b).sum())
        .collect();
    let model_mae = mae(&mean, &ds.y_test);
    let mean_mae = mae(&vec![0.0; ds.y_test.len()], &ds.y_test);
    assert!(model_mae < mean_mae, "ski model {model_mae} !< mean {mean_mae}");
}

#[test]
fn bbmm_training_reaches_cholesky_quality() {
    // Figure-3 parity in miniature: train with both engines, compare MAE
    let ds = generate_sized("parity", 300, 3, 8);
    let train = |use_bbmm: bool| -> f64 {
        let y = ds.y_train.clone();
        let mut op = DenseKernelOp::new(ds.x_train.clone(), Box::new(Rbf::new(1.0, 1.0)), 0.2);
        let mut params = op.params();
        let mut engine: Box<dyn InferenceEngine> = if use_bbmm {
            Box::new(BbmmEngine::default())
        } else {
            Box::new(CholeskyEngine)
        };
        let mut trainer = Trainer::new(TrainConfig {
            iters: 25,
            lr: 0.1,
            ..Default::default()
        });
        trainer.run(&mut params, |raw| {
            op.set_params(raw);
            engine.mll_and_grad(&op, &y)
        });
        op.set_params(&params);
        let k_star = op.cross(&ds.x_test, op.x());
        let diag: Vec<f64> = (0..ds.x_test.rows())
            .map(|i| op.kernel().eval(ds.x_test.row(i), ds.x_test.row(i)))
            .collect();
        let ch =
            bbmm_gp::linalg::cholesky::Cholesky::new_with_jitter(&op.dense()).unwrap();
        let pred = predict(&k_star, &diag, |m| ch.solve_mat(m), &y);
        mae(&pred.mean, &ds.y_test)
    };
    let mae_chol = train(false);
    let mae_bbmm = train(true);
    assert!(
        mae_bbmm < mae_chol * 1.2 + 0.02,
        "bbmm {mae_bbmm} should be within noise of cholesky {mae_chol}"
    );
}

#[test]
fn exact_gp_engines_predict_identically() {
    let ds = generate_sized("pred_parity", 200, 2, 9);
    let mut chol_gp = ExactGp::new(
        ds.x_train.clone(),
        ds.y_train.clone(),
        Box::new(Rbf::new(0.5, 1.0)),
        0.05,
        Engine::Cholesky,
    );
    let mut bbmm_gp_model = ExactGp::new(
        ds.x_train.clone(),
        ds.y_train.clone(),
        Box::new(Rbf::new(0.5, 1.0)),
        0.05,
        Engine::Bbmm(BbmmEngine::new(200, 10, 5, 10)),
    );
    let a = chol_gp.predict(&ds.x_test);
    let b = bbmm_gp_model.predict(&ds.x_test);
    for i in 0..ds.y_test.len() {
        assert!((a.mean[i] - b.mean[i]).abs() < 1e-4, "mean {i}");
        assert!((a.var[i] - b.var[i]).abs() < 1e-3, "var {i}");
    }
}

#[test]
fn kernel_composition_through_engine() {
    // sum and product kernels flow through the blackbox engine unchanged
    use bbmm_gp::kernels::{ProductKernel, SumKernel};
    let ds = generate_sized("compose", 100, 2, 11);
    let y = ds.y_train.clone();
    let sum_k = SumKernel::new(
        Box::new(Rbf::new(0.5, 0.7)),
        Box::new(Matern52::new(0.8, 0.4)),
    );
    let prod_k = ProductKernel::new(
        Box::new(Rbf::new(0.5, 1.0)),
        Box::new(Matern52::new(0.8, 1.0)),
    );
    for kernel in [
        Box::new(sum_k) as Box<dyn bbmm_gp::kernels::Kernel>,
        Box::new(prod_k),
    ] {
        let op = DenseKernelOp::new(ds.x_train.clone(), kernel, 0.1);
        let exact = CholeskyEngine.mll_and_grad(&op, &y);
        let mut bbmm = BbmmEngine::new(100, 64, 5, 12);
        let est = bbmm.mll_and_grad(&op, &y);
        assert!((est.datafit - exact.datafit).abs() / exact.datafit.abs() < 1e-4);
        for p in 0..op.n_params() {
            assert!(
                (est.grad[p] - exact.grad[p]).abs() < 0.3 * (1.0 + exact.grad[p].abs()),
                "grad[{p}]"
            );
        }
    }
}
