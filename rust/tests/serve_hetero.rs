//! Heterogeneous serving hot path, end to end: block-diagonal operator
//! parity, ONE fused iterative solve per mixed-tenant tick (verified via
//! stats counters over TCP), deadline admission control's documented
//! `ERR deadline` line, and backpressure counters round-tripping through
//! the `STATS` verb.

use bbmm_gp::coordinator::{
    handle_request, multi_served_predictor_fused, serve, served_predictor_cached, BatchPolicy,
    DynamicBatcher, Metrics, ServableModel, ServerConfig, TenantSpec,
};
use bbmm_gp::gp::predict::Prediction;
use bbmm_gp::gp::SgprOp;
use bbmm_gp::kernels::{DenseKernelOp, Matern52, Rbf};
use bbmm_gp::linalg::op::{
    solve, AddedDiagOp, BlockDiagOp, LinearOp, LowRankOp, SolveOptions, SolvePlanCache,
};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::Rng;
use std::io::{BufRead, BufReader, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Exact-GP tenant (dense kernel operator) behind the serving seam.
struct ExactTenant {
    op: DenseKernelOp,
    y: Vec<f64>,
}

impl ServableModel for ExactTenant {
    fn op(&self) -> &dyn LinearOp {
        &self.op
    }
    fn cross(&self, xs: &Mat) -> Mat {
        self.op.cross(xs, self.op.x())
    }
    fn prior_diag(&self, xs: &Mat) -> Vec<f64> {
        (0..xs.rows())
            .map(|i| self.op.kernel().eval(xs.row(i), xs.row(i)))
            .collect()
    }
    fn y(&self) -> &[f64] {
        &self.y
    }
}

/// SGPR tenant — its plan is Woodbury **direct**, so a mixed tick with an
/// exact tenant exercises two model families in one fused solve.
struct SgprTenant {
    op: SgprOp,
    y: Vec<f64>,
}

impl ServableModel for SgprTenant {
    fn op(&self) -> &dyn LinearOp {
        &self.op
    }
    fn cross(&self, xs: &Mat) -> Mat {
        self.op.cross_sor(xs)
    }
    fn prior_diag(&self, xs: &Mat) -> Vec<f64> {
        let k = self.op.kernel();
        (0..xs.rows()).map(|i| k.eval(xs.row(i), xs.row(i))).collect()
    }
    fn y(&self) -> &[f64] {
        &self.y
    }
}

fn exact_tenant(n: usize, seed: u64, matern: bool) -> ExactTenant {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let y: Vec<f64> = (0..n)
        .map(|i| (3.0 * x.get(i, 0)).sin() - 0.5 * x.get(i, 1) + 0.02 * rng.normal())
        .collect();
    let kernel: Box<dyn bbmm_gp::kernels::Kernel> = if matern {
        Box::new(Matern52::new(0.6, 0.9))
    } else {
        Box::new(Rbf::new(0.5, 1.0))
    };
    ExactTenant {
        op: DenseKernelOp::new(x, kernel, 0.1),
        y,
    }
}

fn sgpr_tenant(n: usize, m: usize, seed: u64) -> SgprTenant {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let y: Vec<f64> = (0..n).map(|i| (2.0 * x.get(i, 0)).cos() + 0.3 * x.get(i, 1)).collect();
    let mut u = Mat::zeros(m, 2);
    for r in 0..m {
        u.row_mut(r).copy_from_slice(x.row(rng.below(n)));
    }
    SgprTenant {
        op: SgprOp::new(x, u, Box::new(Rbf::new(0.5, 1.0)), 0.1),
        y,
    }
}

/// The block-diagonal operator solves a stacked mixed-size, mixed-family
/// system to the same answer as solving every block on its own — the
/// operator-level statement of the fused serving tick.
#[test]
fn block_diagonal_solve_matches_per_block_sequential() {
    let mut rng = Rng::new(9);
    // exact tenant (dense kernel, n=40) + SGPR-style Woodbury (n=25)
    let exact = exact_tenant(40, 1, false);
    let l = Mat::from_fn(25, 4, |_, _| rng.normal());
    let sgpr = AddedDiagOp::new(LowRankOp::new(l), 0.2);
    let blocks: Vec<&dyn LinearOp> = vec![exact.op(), &sgpr];
    let bd = BlockDiagOp::new(blocks.clone());
    assert_eq!(bd.n(), 65);

    let opts = SolveOptions {
        max_iters: 1000,
        tol: 1e-12,
        precond_rank: 5,
    };
    let b = Mat::from_fn(65, 3, |_, _| rng.normal());
    let stacked = solve(&bd, &b, &opts);
    for (i, &el) in blocks.iter().enumerate() {
        let r = bd.block_range(i);
        let (lo, hi) = (r.start, r.end);
        let mut bi = Mat::zeros(hi - lo, b.cols());
        for r in lo..hi {
            bi.row_mut(r - lo).copy_from_slice(b.row(r));
        }
        let seq = solve(el, &bi, &opts);
        let mut got = Mat::zeros(hi - lo, b.cols());
        for r in lo..hi {
            got.row_mut(r - lo).copy_from_slice(stacked.row(r));
        }
        let rel = got.max_abs_diff(&seq) / seq.fro_norm().max(1e-300);
        assert!(rel < 1e-10, "block {i}: rel diff {rel}");
    }
}

/// Two tenants with different training sizes AND different model families
/// served over TCP: one coalesced tick answers both through exactly ONE
/// fused iterative solve, proven by the `fused=`/`fused_blocks=` counters
/// — which also round-trip through the `STATS` verb.
#[test]
fn mixed_tick_runs_one_fused_solve_over_tcp() {
    let ta = exact_tenant(40, 3, true);
    let tb = sgpr_tenant(60, 12, 4);
    let models: Vec<(String, Box<dyn ServableModel>)> =
        vec![("exact".to_string(), Box::new(ta)), ("sgpr".to_string(), Box::new(tb))];
    let opts = SolveOptions {
        max_iters: 400,
        tol: 1e-10,
        precond_rank: 5,
    };
    let cache = Arc::new(SolvePlanCache::new());
    let metrics = Arc::new(Metrics::new());
    let predictor = multi_served_predictor_fused(models, opts, cache, Arc::clone(&metrics));
    let batcher = Arc::new(DynamicBatcher::new_multi_with_metrics(
        vec![TenantSpec::new("exact", 2), TenantSpec::new("sgpr", 2)],
        BatchPolicy {
            max_batch: 8,
            // a long fill window so both clients' requests land in ONE tick
            max_wait: Duration::from_millis(250),
            ..BatchPolicy::default()
        },
        predictor,
        metrics,
    ));

    let stop = Arc::new(AtomicBool::new(false));
    let config = ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        operator: String::new(),
        shard_count: 1,
        stop: Arc::clone(&stop),
    };
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let srv = {
        let b = Arc::clone(&batcher);
        std::thread::spawn(move || {
            serve(config, b, move |addr| {
                addr_tx.send(addr).unwrap();
            })
            .unwrap();
        })
    };
    let addr = addr_rx.recv().unwrap();

    let mut clients = Vec::new();
    for line in ["exact:0.2,-0.4\n", "sgpr:-0.1,0.3\n"] {
        clients.push(std::thread::spawn(move || {
            let mut conn = std::net::TcpStream::connect(addr).unwrap();
            conn.write_all(line.as_bytes()).unwrap();
            let mut resp = String::new();
            BufReader::new(conn).read_line(&mut resp).unwrap();
            assert!(!resp.starts_with("ERR"), "{resp}");
            let mean: f64 = resp.trim().split(',').next().unwrap().parse().unwrap();
            assert!(mean.is_finite());
        }));
    }
    for c in clients {
        c.join().unwrap();
    }

    // exactly ONE fused solve answered both tenants' blocks
    assert_eq!(batcher.metrics.fused_solves.load(Ordering::Relaxed), 1);
    assert_eq!(batcher.metrics.fused_blocks.load(Ordering::Relaxed), 2);
    assert_eq!(batcher.metrics.batches.load(Ordering::Relaxed), 1);

    // the counters round-trip through the STATS verb
    let mut conn = std::net::TcpStream::connect(addr).unwrap();
    conn.write_all(b"STATS\nQUIT\n").unwrap();
    let mut lines = BufReader::new(conn.try_clone().unwrap()).lines();
    let stats = lines.next().unwrap().unwrap();
    assert!(stats.contains("requests=2"), "{stats}");
    assert!(stats.contains("fused=1"), "{stats}");
    assert!(stats.contains("fused_blocks=2"), "{stats}");
    assert!(stats.contains("shed=0"), "{stats}");
    assert!(stats.contains("tick_p50="), "{stats}");

    stop.store(true, Ordering::Relaxed);
    srv.join().unwrap();
}

/// A tenant with an unmeetable deadline class is shed at admission with
/// the documented `ERR deadline …` line, and the shed counter reaches the
/// STATS summary.
#[test]
fn deadline_shedding_returns_documented_err_line() {
    let echo: bbmm_gp::coordinator::PredictFn = Box::new(|xs: &Mat| Prediction {
        mean: vec![0.0; xs.rows()],
        var: vec![1.0; xs.rows()],
    });
    let b = DynamicBatcher::new(
        2,
        BatchPolicy {
            default_deadline: Some(Duration::from_millis(500)),
            ..BatchPolicy::default()
        },
        echo,
    );
    // no tick history yet → admission has no estimate → served normally
    assert!(!handle_request("0.5,0.5", &b, None).starts_with("ERR"));
    // pathological tick history: ~10s per tick makes a 500ms deadline
    // provably unmeetable, so the next request must shed at admission
    b.metrics.record_tick(10_000_000);
    let resp = handle_request("0.5,0.5", &b, None);
    assert!(resp.starts_with("ERR deadline"), "{resp}");
    assert!(resp.contains("unmeetable"), "{resp}");
    let stats = handle_request("STATS", &b, None);
    assert!(stats.contains("shed=1"), "{stats}");
    assert!(stats.contains("errors=1"), "{stats}");
}

/// `served_predictor_cached` primes the tenant's solve plan at
/// construction — the first request after startup hits a warm cache
/// instead of paying the factorisation/preconditioner build.
#[test]
fn served_predictor_primes_plan_cache_at_startup() {
    let model = exact_tenant(30, 5, false);
    let opts = SolveOptions {
        max_iters: 200,
        tol: 1e-10,
        precond_rank: 5,
    };
    let cache = Arc::new(SolvePlanCache::new());
    let predictor = served_predictor_cached(Box::new(model), opts, Arc::clone(&cache));
    // plan built before any request arrived
    assert_eq!(cache.misses(), 1, "{}", cache.stats());
    let pred = predictor(&Mat::from_vec(1, 2, vec![0.1, -0.2]));
    assert!(pred.mean[0].is_finite() && pred.var[0] >= 0.0);
    assert_eq!(cache.misses(), 1, "{}", cache.stats());
    assert!(cache.hits() >= 1, "{}", cache.stats());
}
