//! Property tests for the iteration-amortised MMM engine: the GEMM
//! micro-kernel vs the naive reference, every [`MmmPlan`] variant vs the
//! dense materialisation, derivative tiles from the cached r² panel vs
//! finite differences, the `Arc<Mat>` sharing seam, plan-aware
//! fingerprints, and the zero-allocation batched iteration loop.

use bbmm_gp::gp::SkiOp;
use bbmm_gp::kernels::{
    DenseKernelOp, Kernel, KernelCov, KernelCovOp, Matern32, Rbf, ShardedCovOp, ShardedKernelOp,
};
use bbmm_gp::linalg::mbcg::{mbcg_batch_stats_ws, mbcg_op, MbcgOptions, MbcgWorkspace};
use bbmm_gp::linalg::op::{
    AddedDiagOp, BatchOp, LinearOp, MmmPlan, Precision, SolveOptions, SolvePlanCache,
};
use bbmm_gp::linalg::preconditioner::{IdentityPrecond, Preconditioner};
use bbmm_gp::tensor::{gemm, simd, Mat};
use bbmm_gp::util::Rng;
use std::sync::Arc;

const PLANS: [MmmPlan; 3] = [MmmPlan::Stream, MmmPlan::CachedDistances, MmmPlan::MaterializeK];

fn rand_mat(r: usize, c: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(r, c, |_, _| rng.normal())
}

fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            let mut s = 0.0;
            for k in 0..a.cols() {
                s += a.get(i, k) * b.get(k, j);
            }
            out.set(i, j, s);
        }
    }
    out
}

#[test]
fn gemm_backed_matmul_matches_naive_on_odd_and_degenerate_shapes() {
    // shapes straddling every register-tile boundary (MR=4, NR=8, KB=256)
    for &(m, k, n) in &[
        (1usize, 1usize, 1usize),
        (2, 3, 5),
        (4, 8, 8),
        (5, 9, 7),
        (7, 255, 9),
        (9, 256, 15),
        (12, 257, 17),
        (33, 70, 40),
        (3, 300, 1),
        (1, 512, 24),
    ] {
        let a = rand_mat(m, k, (m * 1000 + k * 10 + n) as u64);
        let b = rand_mat(k, n, (n * 1000 + k) as u64);
        let got = a.matmul(&b);
        let want = naive_matmul(&a, &b);
        let scale = want.fro_norm().max(1.0);
        assert!(
            got.max_abs_diff(&want) / scale < 1e-10,
            "({m},{k},{n}): rel diff {}",
            got.max_abs_diff(&want) / scale
        );
        // matmul_into writes the identical product into a caller buffer
        let mut out = Mat::zeros(m, n);
        a.matmul_into(&b, &mut out);
        assert!(out.max_abs_diff(&got) == 0.0, "matmul_into must match matmul");
    }
    // degenerate: empty contraction axis
    let a = Mat::zeros(3, 0);
    let b = Mat::zeros(0, 4);
    assert_eq!(a.matmul(&b).shape(), (3, 4));
}

#[test]
fn f32_gemm_backed_matmul_tracks_f64() {
    let a = rand_mat(19, 33, 1);
    let b = rand_mat(33, 11, 2);
    let want = naive_matmul(&a, &b);
    let got32 = a.cast::<f32>().matmul(&b.cast::<f32>());
    let scale = want.fro_norm().max(1.0);
    assert!(got32.cast::<f64>().max_abs_diff(&want) / scale < 1e-4);
}

#[test]
fn unrolled_dot_matches_reference() {
    for &len in &[0usize, 1, 2, 3, 4, 5, 31, 32, 33, 100] {
        let x = rand_mat(1, len, 3 + len as u64);
        let y = rand_mat(1, len, 4 + len as u64);
        let want: f64 = x.data().iter().zip(y.data()).map(|(a, b)| a * b).sum();
        let got = gemm::dot(x.data(), y.data());
        assert!((got - want).abs() < 1e-10 * (1.0 + want.abs()), "len {len}");
    }
}

/// Every plan variant must produce the dense reference product — value
/// AND derivative tiles — to 1e-10 relative, for stationary and
/// non-stationary kernels and shapes that are odd w.r.t. every tile size.
#[test]
fn every_mmm_plan_matches_the_dense_reference() {
    for &(n, t) in &[(37usize, 1usize), (64, 3), (131, 5)] {
        let mut rng = Rng::new(n as u64);
        let x = Mat::from_fn(n, 3, |_, _| rng.uniform_in(-1.0, 1.0));
        let m = Mat::from_fn(n, t, |_, _| rng.normal());
        for kernel in [
            Box::new(Rbf::new(0.6, 1.1)) as Box<dyn Kernel>,
            Box::new(Matern32::new(0.4, 0.8)) as Box<dyn Kernel>,
        ] {
            let reference = KernelCovOp::new(x.clone(), kernel.boxed_clone());
            let kdense = reference.dense();
            let want = kdense.matmul(&m);
            let scale = want.fro_norm().max(1.0);
            for plan in PLANS {
                let cov = KernelCovOp::new(x.clone(), kernel.boxed_clone()).with_plan(plan);
                assert_eq!(cov.plan(), plan);
                let got = cov.matmul(&m);
                assert!(
                    got.max_abs_diff(&want) / scale < 1e-10,
                    "plan {} n={n} t={t}: {}",
                    plan.name(),
                    got.max_abs_diff(&want) / scale
                );
                // derivative products for every kernel parameter
                for p in 0..cov.n_params() {
                    let got_d = cov.dmatmul(p, &m);
                    let want_d = reference.dmatmul(p, &m);
                    let dscale = want_d.fro_norm().max(1.0);
                    assert!(
                        got_d.max_abs_diff(&want_d) / dscale < 1e-10,
                        "plan {} dmatmul({p})",
                        plan.name()
                    );
                }
            }
        }
    }
}

#[test]
fn sharded_op_consumes_every_plan() {
    let n = 83;
    let mut rng = Rng::new(7);
    let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let m = Mat::from_fn(n, 3, |_, _| rng.normal());
    let reference = KernelCovOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.2)));
    let want = reference.dense().matmul(&m);
    let scale = want.fro_norm().max(1.0);
    for plan in PLANS {
        let cov = ShardedCovOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.2)), 5).with_plan(plan);
        let got = cov.matmul(&m);
        assert!(
            got.max_abs_diff(&want) / scale < 1e-10,
            "sharded plan {}: {}",
            plan.name(),
            got.max_abs_diff(&want) / scale
        );
        for p in 0..cov.n_params() {
            let diff = cov.dmatmul(p, &m).max_abs_diff(&reference.dmatmul(p, &m));
            assert!(diff / scale < 1e-10, "sharded plan {} dmatmul({p})", plan.name());
        }
    }
}

/// The cached-r² derivative tile (`dmatmul` under `CachedDistances`) must
/// agree with central finite differences of the value product.
#[test]
fn dmatmul_from_cached_r2_matches_finite_differences() {
    let n = 40;
    let mut rng = Rng::new(11);
    let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let m = Mat::from_fn(n, 2, |_, _| rng.normal());
    let mut cov = KernelCovOp::new(x, Box::new(Rbf::new(0.5, 1.0)))
        .with_plan(MmmPlan::CachedDistances);
    // materialise the r² panel first so both value and derivative tiles
    // demonstrably derive from it
    cov.prepare();
    let raw = cov.kernel().params();
    let h = 1e-6;
    for p in 0..cov.n_params() {
        let analytic = cov.dmatmul(p, &m);
        let mut plus = raw.clone();
        plus[p] += h;
        cov.set_kernel_params(&plus);
        let fp = cov.matmul(&m);
        let mut minus = raw.clone();
        minus[p] -= h;
        cov.set_kernel_params(&minus);
        let fm = cov.matmul(&m);
        cov.set_kernel_params(&raw);
        let mut fd = fp.sub(&fm);
        fd.scale_assign(1.0 / (2.0 * h));
        assert!(
            analytic.max_abs_diff(&fd) < 1e-4,
            "param {p}: {}",
            analytic.max_abs_diff(&fd)
        );
    }
}

#[test]
fn materialized_k_invalidates_on_parameter_update() {
    let n = 30;
    let mut rng = Rng::new(13);
    let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let m = Mat::from_fn(n, 2, |_, _| rng.normal());
    let mut cov = KernelCovOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)))
        .with_plan(MmmPlan::MaterializeK);
    let _ = cov.matmul(&m); // builds K for the current parameters
    let mut raw = cov.kernel().params();
    raw[0] += 0.3;
    cov.set_kernel_params(&raw);
    let reference = {
        let mut k = Box::new(Rbf::new(0.5, 1.0)) as Box<dyn Kernel>;
        k.set_params(&raw);
        KernelCovOp::new(x, k).with_plan(MmmPlan::Stream)
    };
    let got = cov.matmul(&m);
    let want = reference.matmul(&m);
    assert!(
        got.max_abs_diff(&want) < 1e-12,
        "stale K panel served after a hyperparameter update: {}",
        got.max_abs_diff(&want)
    );
}

/// `share_cached` clones share the training inputs and caches by Arc —
/// the fit_sweep memory seam — and stay numerically identical.
#[test]
fn share_cached_shares_inputs_and_matches() {
    let n = 50;
    let mut rng = Rng::new(17);
    let x = Arc::new(Mat::from_fn(n, 3, |_, _| rng.uniform_in(-1.0, 1.0)));
    let m = Mat::from_fn(n, 2, |_, _| rng.normal());
    let a = KernelCovOp::from_shared(Arc::clone(&x), Box::new(Rbf::new(0.5, 1.0)));
    let mut k2 = Box::new(Rbf::new(0.5, 1.0)) as Box<dyn Kernel>;
    let mut p2 = k2.params();
    p2[0] += 0.4;
    k2.set_params(&p2);
    let b = a.share_cached(k2.boxed_clone());
    assert!(Arc::ptr_eq(a.shared_x(), b.shared_x()), "X must be shared, not cloned");
    assert!(Arc::ptr_eq(a.shared_x(), &x));
    // the sibling computes exactly what an independently-built op does
    let independent = KernelCovOp::new((*x).clone(), k2);
    assert!(b.matmul(&m).max_abs_diff(&independent.matmul(&m)) < 1e-12);
    // and the original is unaffected by the sibling's different kernel
    let fresh = KernelCovOp::from_shared(Arc::clone(&x), Box::new(Rbf::new(0.5, 1.0)));
    assert!(a.matmul(&m).max_abs_diff(&fresh.matmul(&m)) == 0.0);
}

/// Switching the materialisation plan changes the operator fingerprint
/// (via `mmm_tag`), so a `SolvePlanCache` rebuilds instead of serving a
/// plan prepared under different product semantics.
#[test]
fn plan_switch_invalidates_cached_solve_plans() {
    let n = 24;
    let mut rng = Rng::new(19);
    let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let cov = KernelCovOp::new(x, Box::new(Rbf::new(0.5, 1.0))).with_plan(MmmPlan::Stream);
    let mut op = AddedDiagOp::new(cov, 0.1);
    let cache = SolvePlanCache::new();
    let opts = SolveOptions::default();
    let fp_stream = op.fingerprint();
    let _ = cache.get_or_plan("slot", &op, &opts);
    op.inner_mut().set_plan(MmmPlan::MaterializeK);
    assert_ne!(fp_stream, op.fingerprint(), "plan must be part of the fingerprint");
    let _ = cache.get_or_plan("slot", &op, &opts);
    assert_eq!(cache.invalidations(), 1, "plan switch must rebuild the slot");
    let _ = cache.get_or_plan("slot", &op, &opts);
    assert_eq!(cache.hits(), 1);
}

/// The acceptance observable: with materialisation plans, `matmul_into`
/// operators, identity preconditioners, and a warm workspace, the batched
/// iteration loop performs ZERO heap allocations (counted by the
/// debug-build allocation counter; release builds report 0 trivially).
#[test]
fn warm_mbcg_batch_iteration_loop_is_allocation_free() {
    let n = 200;
    let b = 3;
    let mut rng = Rng::new(23);
    let x = Mat::from_fn(n, 3, |_, _| rng.uniform_in(-1.0, 1.0));
    let cov = KernelCovOp::new(x, Box::new(Rbf::new(0.6, 1.0))).with_plan(MmmPlan::MaterializeK);
    let sigma2s: Vec<f64> = (0..b).map(|i| 0.1 + 0.05 * i as f64).collect();
    let batch = BatchOp::shared(&cov, sigma2s);
    let bs: Vec<Mat> = (0..b)
        .map(|_| Mat::from_fn(n, 2, |_, _| rng.normal()))
        .collect();
    let b_refs: Vec<&Mat> = bs.iter().collect();
    let id = IdentityPrecond;
    let preconds: Vec<&dyn Preconditioner> =
        (0..b).map(|_| &id as &dyn Preconditioner).collect();
    let opts = MbcgOptions {
        max_iters: 8,
        tol: 0.0,
        n_solve_only: usize::MAX,
    };
    let mut ws = MbcgWorkspace::new();
    // call 1: warms the pool, the K panel, the workspace, and per-thread
    // scratch; its loop may allocate while those come up
    let (_r1, _s1) = mbcg_batch_stats_ws(&batch, &b_refs, &preconds, &opts, &mut ws);
    // call 2: the steady state a training loop or serving tick lives in
    let (r2, s2) = mbcg_batch_stats_ws(&batch, &b_refs, &preconds, &opts, &mut ws);
    assert_eq!(
        s2.loop_allocs, 0,
        "warm batched iteration loop must not touch the heap (saw {} allocations)",
        s2.loop_allocs
    );
    // and it still solves: parity against the one-shot entry point
    let (r_ref, _) = bbmm_gp::linalg::mbcg::mbcg_batch_stats(&batch, &b_refs, &preconds, &opts);
    for (a, c) in r2.iter().zip(r_ref.iter()) {
        assert_eq!(a.iterations, c.iterations);
        assert!(a.solves.max_abs_diff(&c.solves) == 0.0);
    }
}

/// The runtime dispatcher must pick a lane set consistent with the build
/// target, and the CI forced-scalar leg (`BBMM_FORCE_SCALAR`) must pin it
/// to the portable path — the expectation is computed from the env so the
/// same test is green on both CI legs.
#[test]
fn runtime_dispatch_is_consistent_with_target_and_env() {
    let d = simd::active();
    let forced = std::env::var("BBMM_FORCE_SCALAR")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    if forced {
        assert_eq!(
            d,
            simd::Dispatch::Scalar,
            "BBMM_FORCE_SCALAR must pin the scalar path"
        );
    }
    match d {
        simd::Dispatch::Scalar => {
            assert_eq!((d.lanes_f64(), d.lanes_f32()), (1, 1));
        }
        simd::Dispatch::Avx2Fma => {
            assert!(cfg!(target_arch = "x86_64"), "AVX2 selected off-target");
            assert_eq!((d.lanes_f64(), d.lanes_f32()), (4, 8));
        }
        simd::Dispatch::Neon => {
            assert!(cfg!(target_arch = "aarch64"), "NEON selected off-target");
            assert_eq!((d.lanes_f64(), d.lanes_f32()), (2, 4));
        }
    }
    // the mixed-precision premise: f32 never has fewer lanes than f64
    assert!(d.lanes_f32() >= d.lanes_f64());
}

/// The explicit-SIMD f64 GEMM must be near-bit-comparable to the scalar
/// register-blocked reference: same k-order accumulation, FMA contraction
/// differences only — gated at 1e-12 relative. Skipped (vacuously green)
/// under scalar dispatch, where there is no second implementation to
/// compare.
#[test]
fn simd_f64_gemm_is_near_bit_comparable_to_scalar() {
    for &(m, k, n) in &[(5usize, 9usize, 7usize), (9, 256, 15), (12, 257, 17), (33, 300, 40)] {
        let a = rand_mat(m, k, (31 * m + k) as u64);
        let b = rand_mat(k, n, (37 * n + k) as u64);
        let want = naive_matmul(&a, &b);
        let mut out = Mat::zeros(m, n);
        if !simd::gemm_f64(a.data(), b.data(), out.data_mut(), m, k, n) {
            return; // scalar dispatch (or BBMM_FORCE_SCALAR): nothing to compare
        }
        let scale = want.fro_norm().max(1.0);
        assert!(
            out.max_abs_diff(&want) / scale < 1e-12,
            "({m},{k},{n}): rel diff {}",
            out.max_abs_diff(&want) / scale
        );
    }
}

/// Mixed-precision mBCG solves must track the f64 reference across the
/// operator families that carry the knob — the exact dense operator and
/// the sharded operator, under both streaming plans — and SKI must not
/// pretend to carry it at all. Typical solve drift is ~1e-5 relative
/// (f32 tiles, f64 reductions); the gate leaves conditioning headroom.
#[test]
fn mixed_precision_solves_track_f64_across_operators() {
    let n = 96;
    let mut rng = Rng::new(29);
    let x = Mat::from_fn(n, 3, |_, _| rng.uniform_in(-1.0, 1.0));
    let y = Mat::from_fn(n, 1, |_, _| rng.normal());
    // n_solve_only == cols: solve-only, no tridiagonal recovery needed
    let opts = MbcgOptions {
        max_iters: 200,
        tol: 1e-12,
        n_solve_only: 1,
    };
    let id = |m: &Mat| m.clone();
    let kern = || Box::new(Rbf::new(0.6, 1.1)) as Box<dyn Kernel>;
    for plan in [MmmPlan::Stream, MmmPlan::CachedDistances] {
        // exact dense operator: mean weights K̂⁻¹y
        let f64_op = AddedDiagOp::new(KernelCovOp::new(x.clone(), kern()).with_plan(plan), 1.0);
        let mix_op = AddedDiagOp::new(
            KernelCovOp::new(x.clone(), kern())
                .with_plan(plan)
                .with_precision(Precision::Mixed),
            1.0,
        );
        assert!(mix_op.inner().mixed_active());
        let want = mbcg_op(&f64_op, &y, id, &opts).solves;
        let got = mbcg_op(&mix_op, &y, id, &opts).solves;
        let rel = got.max_abs_diff(&want) / want.fro_norm().max(1.0);
        assert!(rel < 5e-4, "exact plan {}: solve rel diff {rel}", plan.name());
        // sharded operator, same contract
        let mut sh64 = ShardedKernelOp::new(x.clone(), kern(), 1.0, 4);
        sh64.set_plan(plan);
        let mut shmx = ShardedKernelOp::new(x.clone(), kern(), 1.0, 4)
            .with_precision(Precision::Mixed);
        shmx.set_plan(plan);
        let want_s = mbcg_op(&sh64, &y, id, &opts).solves;
        let got_s = mbcg_op(&shmx, &y, id, &opts).solves;
        let rel_s = got_s.max_abs_diff(&want_s) / want_s.fro_norm().max(1.0);
        assert!(rel_s < 5e-4, "sharded plan {}: solve rel diff {rel_s}", plan.name());
    }
    // predictive variances: the quadratic form k*ᵀ K̂⁻¹ k* with the same
    // f64 cross-covariances on both sides, isolating the mixed solve
    let xs = Mat::from_fn(7, 3, |_, _| rng.uniform_in(-1.0, 1.0));
    let d64 = DenseKernelOp::new(x.clone(), kern(), 1.0);
    let dmx = DenseKernelOp::new(x.clone(), kern(), 1.0).with_precision(Precision::Mixed);
    let kstar = d64.cross(&xs, d64.x()); // 7×n, f64 on both sides
    let rhs = kstar.transpose();
    let opts7 = MbcgOptions { n_solve_only: rhs.cols(), ..opts };
    let q64 = kstar.matmul(&mbcg_op(&d64, &rhs, id, &opts7).solves);
    let qmx = kstar.matmul(&mbcg_op(&dmx, &rhs, id, &opts7).solves);
    for i in 0..xs.rows() {
        let (a, b) = (q64.get(i, i), qmx.get(i, i));
        assert!(
            (a - b).abs() / (1.0 + a.abs()) < 5e-4,
            "variance term {i}: {a} vs {b}"
        );
    }
    // SKI is grid-structured (Toeplitz over an inducing grid) — there is
    // no stationary tile pass for Mixed to shorten, so it advertises no
    // precision bit and its products stay pure f64 ("degrades, never
    // lies" — the knob must not change SKI fingerprints or numerics).
    let z: Vec<f64> = (0..n).map(|i| i as f64 / n as f64).collect();
    let ski = SkiOp::new(z, 32, kern(), 1.0);
    assert_eq!(
        LinearOp::mmm_tag(&ski) >> 8,
        0,
        "SKI must not advertise a precision tag bit"
    );
}
