//! Operator-algebra parity properties: every composed operator must match
//! its dense materialisation in `matmul` / `dmatmul` / `diag` to 1e-10,
//! across composition shapes (`AddedDiag(Sum(…))`, `Interp`, `LowRank`,
//! `Sharded`) and shard counts {1, 3, 7}; and the generic solve dispatcher
//! must reproduce a dense Cholesky reference for **every** model family —
//! exact, SGPR, SKI, and sharded — through one code path.
//!
//! Precision: the algebra's accumulation type is f64 (1e-10 bounds); the
//! f32 surface is the mixed-precision sharded path (`matmul_scalar::<f32>`,
//! kernel entries evaluated in f64, contracted in f32), checked against the
//! same dense reference at f32 round-off.

use bbmm_gp::gp::{SgprOp, SkiOp};
use bbmm_gp::kernels::{DenseKernelOp, Rbf, ShardedKernelOp};
use bbmm_gp::linalg::cholesky::Cholesky;
use bbmm_gp::linalg::op::{
    solve, solve_strategy, AddedDiagOp, DenseOp, DiagOp, InterpOp, LinearOp, LowRankOp, ScaledOp,
    SolveHint, SolveOptions, SparseInterp, SumOp, ToeplitzLinOp,
};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::Rng;

const TOL: f64 = 1e-10;

fn spd(n: usize, rng: &mut Rng) -> Mat {
    let g = Mat::from_fn(n, n, |_, _| rng.normal());
    let mut a = g.t_matmul(&g);
    a.add_diag(0.3 * n as f64);
    a.symmetrize();
    a
}

/// Assert full matmul/diag/row/entry parity between an operator and its
/// dense materialisation.
fn assert_parity(op: &dyn LinearOp, want: &Mat, rng: &mut Rng, label: &str) {
    let n = op.n();
    assert_eq!(op.shape(), (n, n), "{label}: shape");
    let t = 1 + rng.below(4);
    let m = Mat::from_fn(n, t, |_, _| rng.normal());
    let scale = 1.0 + want.fro_norm();
    assert!(
        op.matmul(&m).max_abs_diff(&want.matmul(&m)) < TOL * scale,
        "{label}: matmul"
    );
    assert!(op.dense().max_abs_diff(want) < TOL * scale, "{label}: dense");
    let d = op.diag();
    for i in 0..n {
        assert!((d[i] - want.get(i, i)).abs() < TOL * scale, "{label}: diag {i}");
    }
    for &i in &[0, n / 2, n - 1] {
        let r = op.row(i);
        for j in 0..n {
            assert!(
                (r[j] - want.get(i, j)).abs() < TOL * scale,
                "{label}: row {i} col {j}"
            );
        }
        assert!(
            (op.entry(i, (i + 1) % n) - want.get(i, (i + 1) % n)).abs() < TOL * scale,
            "{label}: entry {i}"
        );
    }
    // dmatmul parity by central differences is covered per-model in unit
    // tests; here check the generic noise-parameter layout when present
    if op.n_params() > 0 {
        if let Some((_, sigma2)) = op.noise_split() {
            let dm = op.dmatmul(op.n_params() - 1, &m);
            let mut want_dm = m.clone();
            want_dm.scale_assign(sigma2);
            assert!(dm.max_abs_diff(&want_dm) < TOL * scale, "{label}: noise dmatmul");
        }
    }
}

#[test]
fn prop_added_diag_sum_scaled_compositions_match_dense() {
    let mut rng = Rng::new(1);
    for trial in 0..10 {
        let n = 8 + rng.below(40);
        let a = spd(n, &mut rng);
        let b = spd(n, &mut rng);
        let l = Mat::from_fn(n, 1 + rng.below(5), |_, _| rng.normal());
        let dvec: Vec<f64> = (0..n).map(|_| 0.1 + rng.uniform()).collect();
        let c = 0.5 + rng.uniform();
        let sigma2 = 0.05 + rng.uniform();
        // AddedDiag(Sum(Sum(Scaled(Dense), LowRank), Diag))
        let op = AddedDiagOp::new(
            SumOp::new(
                SumOp::new(ScaledOp::new(DenseOp::new(a.clone()), c), LowRankOp::new(l.clone())),
                DiagOp::new(dvec.clone()),
            ),
            sigma2,
        );
        let mut want = a.clone();
        want.scale_assign(c);
        want.add_assign(&l.matmul_t(&l));
        for i in 0..n {
            let v = want.get(i, i) + dvec[i] + sigma2;
            want.set(i, i, v);
        }
        assert_parity(&op, &want, &mut rng, &format!("compose trial {trial}"));
    }
}

#[test]
fn prop_interp_sandwich_matches_dense() {
    let mut rng = Rng::new(2);
    for trial in 0..8 {
        let n = 10 + rng.below(40);
        let m = 8 + rng.below(30);
        let z: Vec<f64> = (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let w = SparseInterp::new(&z, -1.1, 1.1, m);
        let col: Vec<f64> = (0..m)
            .map(|i| (-0.5 * (i as f64 * 0.2).powi(2)).exp())
            .collect();
        let inner = ToeplitzLinOp::new(col);
        let wd = w.to_dense();
        let td = inner.dense();
        let want_cov = wd.matmul(&td).matmul_t(&wd);
        let sigma2 = 0.1 + rng.uniform();
        let op = AddedDiagOp::new(InterpOp::new(w, inner), sigma2);
        let mut want = want_cov.clone();
        want.add_diag(sigma2);
        assert_parity(&op, &want, &mut rng, &format!("interp trial {trial}"));
    }
}

#[test]
fn prop_lowrank_woodbury_solve_is_exact() {
    let mut rng = Rng::new(3);
    for trial in 0..10 {
        let n = 10 + rng.below(60);
        let k = 1 + rng.below(6);
        let l = Mat::from_fn(n, k, |_, _| rng.normal());
        let sigma2 = 0.05 + rng.uniform();
        let op = AddedDiagOp::new(LowRankOp::new(l.clone()), sigma2);
        let mut want = l.matmul_t(&l);
        want.add_diag(sigma2);
        assert_parity(&op, &want, &mut rng, &format!("lowrank trial {trial}"));
        // structure advertises Woodbury, and the dispatched solve is exact
        assert_eq!(solve_strategy(&op), SolveHint::Woodbury);
        let b = Mat::from_fn(n, 1 + rng.below(3), |_, _| rng.normal());
        let got = solve(&op, &b, &SolveOptions::default());
        let reference = Cholesky::new_with_jitter(&want).unwrap().solve_mat(&b);
        assert!(
            got.max_abs_diff(&reference) < 1e-8,
            "woodbury solve trial {trial}"
        );
    }
}

#[test]
fn prop_sharded_operator_matches_dense_across_shard_counts() {
    let mut rng = Rng::new(4);
    for trial in 0..6 {
        let n = 12 + rng.below(50);
        let x = Mat::from_fn(n, 1 + rng.below(3), |_, _| rng.uniform_in(-1.0, 1.0));
        let noise = 0.05 + 0.2 * rng.uniform();
        let dense = DenseKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), noise);
        let want = dense.dense();
        let m = Mat::from_fn(n, 2, |_, _| rng.normal());
        let want_mm = want.matmul(&m);
        for &s in &[1usize, 3, 7] {
            let op = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), noise, s);
            assert_parity(
                &op,
                &want,
                &mut rng,
                &format!("sharded trial {trial} shards {s}"),
            );
            // kernel-parameter derivative operators shard identically
            for p in 0..LinearOp::n_params(&dense) {
                assert!(
                    op.dmatmul(p, &m).max_abs_diff(&dense.dmatmul(p, &m)) < TOL,
                    "sharded dmatmul trial {trial} shards {s} param {p}"
                );
            }
            // f32 surface: mixed-precision shard contraction vs the same
            // dense reference, at f32 round-off (the algebra accumulates
            // the f32 path in f32 by design)
            let got32 = op.matmul_scalar::<f32>(&m.cast());
            assert!(
                got32.cast::<f64>().max_abs_diff(&want_mm) < 1e-3 * (1.0 + want_mm.fro_norm()),
                "sharded f32 trial {trial} shards {s}"
            );
        }
    }
}

#[test]
fn prop_sharded_mmm_backend_composes_through_sharded_op() {
    // ShardedOp lifts any ShardedMmm backend (the seam later per-device
    // backends implement) into the algebra: compose it with AddedDiagOp
    // and it must match the dense reference and solve through the
    // dispatcher like everything else. The diagonal is supplied up front
    // (with_diag) so preconditioner builds stay O(n).
    use bbmm_gp::linalg::mbcg::ShardedMmm;
    use bbmm_gp::linalg::op::ShardedOp;
    use std::ops::Range;

    struct DenseSharded {
        a: Mat,
        shards: Vec<Range<usize>>,
    }
    impl ShardedMmm for DenseSharded {
        fn n(&self) -> usize {
            self.a.rows()
        }
        fn n_shards(&self) -> usize {
            self.shards.len()
        }
        fn shard_rows(&self, s: usize) -> Range<usize> {
            self.shards[s].clone()
        }
        fn shard_matmul(&self, s: usize, m: &Mat, out: &mut [f64]) {
            let t = m.cols();
            for (ri, i) in self.shards[s].clone().enumerate() {
                let arow = self.a.row(i);
                let orow = &mut out[ri * t..(ri + 1) * t];
                for (j, &av) in arow.iter().enumerate() {
                    let mrow = m.row(j);
                    for c in 0..t {
                        orow[c] += av * mrow[c];
                    }
                }
            }
        }
    }

    let mut rng = Rng::new(6);
    for &s in &[1usize, 3, 7] {
        let n = 20 + rng.below(30);
        let g = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut a = g.t_matmul(&g);
        a.symmetrize();
        let diag: Vec<f64> = (0..n).map(|i| a.get(i, i)).collect();
        let shards: Vec<Range<usize>> = (0..s).map(|k| (k * n / s)..((k + 1) * n / s)).collect();
        let sigma2 = 0.2 + rng.uniform();
        let mut want = a.clone();
        want.add_diag(sigma2);
        let backend = DenseSharded { a, shards };
        let op = AddedDiagOp::new(ShardedOp::new(backend).with_diag(diag), sigma2);
        let m = Mat::from_fn(n, 3, |_, _| rng.normal());
        let scale = 1.0 + want.fro_norm();
        assert!(
            op.matmul(&m).max_abs_diff(&want.matmul(&m)) < TOL * scale,
            "shards {s}: matmul"
        );
        for (i, d) in op.diag().iter().enumerate() {
            assert!((d - want.get(i, i)).abs() < TOL * scale, "shards {s}: diag {i}");
        }
        let b = Mat::from_fn(n, 2, |_, _| rng.normal());
        let got = solve(
            &op,
            &b,
            &SolveOptions {
                max_iters: 4 * n,
                tol: 1e-12,
                precond_rank: 4,
            },
        );
        let reference = Cholesky::new_with_jitter(&want).unwrap().solve_mat(&b);
        assert!(got.max_abs_diff(&reference) < 1e-6, "shards {s}: solve");
    }
}

#[test]
fn all_model_families_solve_through_the_generic_dispatcher() {
    let mut rng = Rng::new(5);
    let n = 60;
    let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let y = Mat::from_fn(n, 1, |_, _| rng.normal());
    let opts = SolveOptions {
        max_iters: 4 * n,
        tol: 1e-12,
        precond_rank: 5,
    };
    let check = |op: &dyn LinearOp, label: &str, tol: f64| {
        let reference = Cholesky::new_with_jitter(&op.dense()).unwrap().solve_mat(&y);
        let got = solve(op, &y, &opts);
        assert!(
            got.max_abs_diff(&reference) < tol,
            "{label}: {} (strategy {:?})",
            got.max_abs_diff(&reference),
            solve_strategy(op)
        );
    };
    // exact (iterative mBCG + pivoted-Cholesky preconditioner)
    let exact = DenseKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.1);
    check(&exact, "exact", 1e-6);
    // sharded exact (same path, shard-assembled matmul)
    let sharded = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.1, 7);
    check(&sharded, "sharded", 1e-6);
    // SGPR (direct Woodbury — no CG at all)
    let mut u = Mat::zeros(12, 2);
    for r in 0..12 {
        let src = r * 5 % n;
        u.row_mut(r).copy_from_slice(x.row(src));
    }
    let sgpr = SgprOp::new(x.clone(), u, Box::new(Rbf::new(0.5, 1.0)), 0.1);
    assert_eq!(solve_strategy(&sgpr), SolveHint::Woodbury);
    check(&sgpr, "sgpr", 1e-7);
    // SKI (iterative over the interpolation sandwich)
    let z: Vec<f64> = (0..n).map(|i| x.get(i, 0)).collect();
    let ski = SkiOp::new(z, 64, Box::new(Rbf::new(0.5, 1.0)), 0.1);
    check(&ski, "ski", 1e-5);
}
