//! Batch-axis properties: `mbcg_batch` must reproduce a loop of generic
//! `solve` calls to 1e-10 relative across **all four model families**
//! (exact, SGPR, SKI, sharded) stacked in one `BatchOp`; per-system early
//! stopping must freeze converged systems; and the `SolvePlanCache` must
//! hit/miss/invalidate correctly over real model operators.

use bbmm_gp::gp::{SgprOp, SkiOp};
use bbmm_gp::kernels::{DenseKernelOp, Rbf, ShardedKernelOp};
use bbmm_gp::linalg::mbcg::{mbcg_batch, MbcgOptions};
use bbmm_gp::linalg::op::{
    plan_batch, solve, solve_batch, solve_cached, BatchOp, LinearOp, SolveOptions, SolvePlan,
    SolvePlanCache,
};
use bbmm_gp::linalg::preconditioner::{IdentityPrecond, Preconditioner};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::Rng;

/// One same-n operator per model family: exact (fused dense backend),
/// sharded, SGPR (low-rank Woodbury composition), SKI (interp sandwich).
fn four_families(n: usize, seed: u64) -> (Vec<Box<dyn LinearOp>>, Vec<&'static str>) {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let exact = DenseKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.1);
    let sharded = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.4, 1.1)), 0.2, 3);
    let u = Mat::from_fn(12, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let sgpr = SgprOp::new(x.clone(), u, Box::new(Rbf::new(0.5, 1.0)), 0.15);
    let z: Vec<f64> = (0..n).map(|i| x.get(i, 0)).collect();
    let ski = SkiOp::new(z, 40, Box::new(Rbf::new(0.3, 1.0)), 0.12);
    let ops: Vec<Box<dyn LinearOp>> =
        vec![Box::new(exact), Box::new(sharded), Box::new(sgpr), Box::new(ski)];
    (ops, vec!["exact", "sharded", "sgpr", "ski"])
}

#[test]
fn mbcg_batch_matches_a_loop_of_solve_calls_across_all_four_families() {
    let n = 60;
    let (ops, names) = four_families(n, 1);
    let els: Vec<&dyn LinearOp> = ops.iter().map(|o| o.as_ref()).collect();
    let batch = BatchOp::new(els);
    assert_eq!(batch.len(), 4);
    let mut rng = Rng::new(2);
    let bs: Vec<Mat> = (0..4).map(|_| Mat::from_fn(n, 2, |_, _| rng.normal())).collect();
    let b_refs: Vec<&Mat> = bs.iter().collect();
    // b ≥ 4 systems through ONE iteration loop, tight tolerance
    let id = IdentityPrecond;
    let preconds: Vec<&dyn Preconditioner> = (0..4).map(|_| &id as &dyn Preconditioner).collect();
    let results = mbcg_batch(
        &batch,
        &b_refs,
        &preconds,
        &MbcgOptions {
            max_iters: 4 * n,
            tol: 1e-13,
            n_solve_only: usize::MAX,
        },
    );
    let opts = SolveOptions {
        max_iters: 4 * n,
        tol: 1e-13,
        precond_rank: 5,
    };
    for k in 0..4 {
        // the sequential baseline: the generic dispatcher, one op at a time
        // (direct Woodbury for SGPR, mBCG elsewhere)
        let want = solve(&ops[k], &bs[k], &opts);
        let scale = 1.0 + want.fro_norm();
        assert!(
            results[k].solves.max_abs_diff(&want) < 1e-10 * scale,
            "family {}: {}",
            names[k],
            results[k].solves.max_abs_diff(&want)
        );
    }
}

#[test]
fn solve_batch_matches_a_loop_of_solve_calls_across_all_four_families() {
    let n = 55;
    let (ops, names) = four_families(n, 3);
    let els: Vec<&dyn LinearOp> = ops.iter().map(|o| o.as_ref()).collect();
    let batch = BatchOp::new(els);
    let opts = SolveOptions {
        max_iters: 4 * n,
        tol: 1e-13,
        precond_rank: 5,
    };
    let plans = plan_batch(&batch, &opts);
    // SGPR's plan must be the direct Woodbury one — no CG for it even
    // inside the batch
    assert!(plans[2].is_direct(), "sgpr should plan direct Woodbury");
    let mut rng = Rng::new(4);
    let bs: Vec<Mat> = (0..4).map(|_| Mat::from_fn(n, 3, |_, _| rng.normal())).collect();
    let b_refs: Vec<&Mat> = bs.iter().collect();
    let plan_refs: Vec<&SolvePlan> = plans.iter().collect();
    let got = solve_batch(&batch, &plan_refs, &b_refs, &opts);
    for k in 0..4 {
        let want = solve(&ops[k], &bs[k], &opts);
        let scale = 1.0 + want.fro_norm();
        assert!(
            got[k].max_abs_diff(&want) < 1e-10 * scale,
            "family {}: {}",
            names[k],
            got[k].max_abs_diff(&want)
        );
    }
}

#[test]
fn per_system_early_stopping_leaves_other_systems_running() {
    // four copies of one covariance at very different noise levels: the
    // high-noise (well-conditioned) systems converge and freeze while the
    // low-noise one keeps iterating — per-system counts must differ
    let n = 80;
    let mut rng = Rng::new(5);
    let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let cov = bbmm_gp::kernels::KernelCovOp::new(x, Box::new(Rbf::new(0.5, 1.0)));
    let sigma2s = vec![5.0, 1e-4, 2.0, 0.5];
    let batch = BatchOp::shared(&cov, sigma2s.clone());
    let bs: Vec<Mat> = (0..4).map(|_| Mat::from_fn(n, 2, |_, _| rng.normal())).collect();
    let b_refs: Vec<&Mat> = bs.iter().collect();
    let id = IdentityPrecond;
    let preconds: Vec<&dyn Preconditioner> = (0..4).map(|_| &id as &dyn Preconditioner).collect();
    let opts = MbcgOptions {
        max_iters: 2 * n,
        tol: 1e-10,
        n_solve_only: usize::MAX,
    };
    let results = mbcg_batch(&batch, &b_refs, &preconds, &opts);
    assert!(
        results[0].iterations < results[1].iterations,
        "σ²=5.0 must freeze before σ²=1e-4: {} vs {}",
        results[0].iterations,
        results[1].iterations
    );
    // frozen system is *converged*, not truncated
    assert!(results[0].final_residuals.iter().all(|&r| r < 1e-10));
    // and every system still matches its standalone dispatch
    let solve_opts = SolveOptions {
        max_iters: 2 * n,
        tol: 1e-10,
        precond_rank: 0,
    };
    for (k, res) in results.iter().enumerate() {
        let want = batch.with_element(k, |op| solve(op, &bs[k], &solve_opts));
        let scale = 1.0 + want.fro_norm();
        assert!(
            res.solves.max_abs_diff(&want) < 1e-8 * scale,
            "system {k}: {}",
            res.solves.max_abs_diff(&want)
        );
    }
}

#[test]
fn solve_cached_round_trips_across_model_families() {
    let n = 50;
    let (ops, names) = four_families(n, 6);
    let cache = SolvePlanCache::new();
    let opts = SolveOptions {
        max_iters: 4 * n,
        tol: 1e-13,
        precond_rank: 5,
    };
    let mut rng = Rng::new(7);
    let b = Mat::from_fn(n, 2, |_, _| rng.normal());
    for (op, name) in ops.iter().zip(names.iter().copied()) {
        let got = solve_cached(&cache, name, op.as_ref(), &b, &opts);
        let want = solve(op.as_ref(), &b, &opts);
        let scale = 1.0 + want.fro_norm();
        assert!(got.max_abs_diff(&want) < 1e-10 * scale, "family {name}");
    }
    assert_eq!(cache.misses(), 4);
    assert_eq!(cache.hits(), 0);
    // second pass over every family hits
    for (op, name) in ops.iter().zip(names.iter().copied()) {
        let _ = solve_cached(&cache, name, op.as_ref(), &b, &opts);
    }
    assert_eq!(cache.hits(), 4);
    assert_eq!(cache.invalidations(), 0);
}
