//! Artifact integration tests: load every AOT HLO artifact through the
//! PJRT runtime, execute it, and cross-check the numerics against the
//! native Rust engines. Skipped (loudly) when `make artifacts` has not run.

use bbmm_gp::gp::mll::{CholeskyEngine, InferenceEngine};
use bbmm_gp::kernels::{DenseKernelOp, Matern52, Rbf};
use bbmm_gp::linalg::mbcg::tridiag_from_coeffs;
use bbmm_gp::linalg::tridiag::SymTridiagEig;
use bbmm_gp::runtime::{default_artifact_dir, Runtime, TensorF32};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::Rng;

const N: usize = 256;
const D: usize = 4;
const T: usize = 8;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = default_artifact_dir();
    let rt = Runtime::cpu(&dir).ok()?;
    if !rt.backend_available() {
        eprintln!("SKIP: pjrt backend not compiled in — build with `--features pjrt`");
        return None;
    }
    if rt.available().is_empty() {
        eprintln!("SKIP: no artifacts in {dir:?} — run `make artifacts`");
        return None;
    }
    Some(rt)
}

fn problem(seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut x = vec![0f32; N * D];
    for v in x.iter_mut() {
        *v = rng.uniform_in(-1.0, 1.0) as f32;
    }
    let mut y = vec![0f32; N];
    for i in 0..N {
        let xi = &x[i * D..(i + 1) * D];
        y[i] = (3.0 * xi[0]).sin() + 0.5 * xi[1] + 0.05 * rng.normal() as f32;
    }
    let mut z = vec![0f32; N * T];
    for v in z.iter_mut() {
        *v = rng.rademacher() as f32;
    }
    (x, y, z)
}

fn native_op(x: &[f32], kind: &str, params: &[f32; 3]) -> DenseKernelOp {
    let x64 = Mat::from_vec(N, D, x.iter().map(|&v| v as f64).collect());
    let kernel: Box<dyn bbmm_gp::kernels::Kernel> = match kind {
        "matern52" => Box::new(Matern52::new((params[0] as f64).exp(), (params[1] as f64).exp())),
        _ => Box::new(Rbf::new((params[0] as f64).exp(), (params[1] as f64).exp())),
    };
    DenseKernelOp::new(x64, kernel, (params[2] as f64).exp())
}

#[test]
fn every_artifact_on_disk_loads_and_compiles() {
    let Some(mut rt) = runtime_or_skip() else { return };
    for name in rt.available() {
        if name == "manifest" {
            continue;
        }
        rt.load(&name).unwrap_or_else(|e| panic!("load {name}: {e}"));
    }
    assert!(!rt.loaded_names().is_empty());
}

#[test]
fn mll_artifacts_match_native_engines() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let (x, y, z) = problem(7);
    let params = [-0.5f32, 0.0, -2.0];
    for kind in ["rbf", "matern52"] {
        let name = format!("mll_{kind}_n{N}_d{D}_t{T}_p20");
        if !rt.artifact_exists(&name) {
            eprintln!("SKIP {name}");
            continue;
        }
        rt.load(&name).unwrap();
        let outs = rt
            .execute_f32(
                &name,
                &[
                    TensorF32 {
                        data: &x,
                        dims: vec![N as i64, D as i64],
                    },
                    TensorF32 {
                        data: &y,
                        dims: vec![N as i64],
                    },
                    TensorF32 {
                        data: &z,
                        dims: vec![N as i64, T as i64],
                    },
                    TensorF32 {
                        data: &params,
                        dims: vec![3],
                    },
                ],
            )
            .unwrap();
        assert_eq!(outs.len(), 6, "{kind}: u0, datafit, alphas, betas, quad, trace");
        let datafit = outs[1][0] as f64;

        // Rust-side SLQ assembly
        let (alphas, betas) = (&outs[2], &outs[3]);
        let p = alphas.len() / T;
        let mut logdet = 0.0;
        for c in 0..T {
            let a: Vec<f64> = (0..p).map(|j| alphas[j * T + c] as f64).collect();
            let b: Vec<f64> = (0..p).map(|j| betas[j * T + c] as f64).collect();
            let eff = a.iter().take_while(|v| v.abs() > 0.0).count();
            if eff == 0 {
                continue;
            }
            let tri = tridiag_from_coeffs(&a[..eff], &b[..eff.saturating_sub(1)]);
            let eig = SymTridiagEig::new(&tri.diag, &tri.offdiag);
            logdet += N as f64 * eig.log_quadrature();
        }
        logdet /= T as f64;

        let op = native_op(&x, kind, &params);
        let y64: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        let exact = CholeskyEngine.mll_and_grad(&op, &y64);
        assert!(
            (datafit - exact.datafit).abs() / exact.datafit.abs() < 1e-3,
            "{kind} datafit {datafit} vs {}",
            exact.datafit
        );
        assert!(
            (logdet - exact.logdet).abs() / exact.logdet.abs().max(1.0) < 0.15,
            "{kind} logdet {logdet} vs {}",
            exact.logdet
        );
        // gradient assembly vs exact
        for j in 0..3 {
            let g = 0.5 * (-(outs[4][j] as f64) + outs[5][j] as f64);
            assert!(
                (g - exact.grad[j]).abs() < 0.3 * (1.0 + exact.grad[j].abs()),
                "{kind} grad[{j}] {g} vs {}",
                exact.grad[j]
            );
        }
    }
}

#[test]
fn predict_artifacts_match_native_posterior() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let (x, y, _z) = problem(8);
    let params = [-0.5f32, 0.0, -2.0];
    let m = 64usize;
    let mut rng = Rng::new(9);
    let mut xs = vec![0f32; m * D];
    for v in xs.iter_mut() {
        *v = rng.uniform_in(-1.0, 1.0) as f32;
    }
    for kind in ["rbf", "matern52"] {
        let name = format!("predict_{kind}_n{N}_d{D}_m{m}");
        if !rt.artifact_exists(&name) {
            eprintln!("SKIP {name}");
            continue;
        }
        rt.load(&name).unwrap();
        let outs = rt
            .execute_f32(
                &name,
                &[
                    TensorF32 {
                        data: &x,
                        dims: vec![N as i64, D as i64],
                    },
                    TensorF32 {
                        data: &y,
                        dims: vec![N as i64],
                    },
                    TensorF32 {
                        data: &xs,
                        dims: vec![m as i64, D as i64],
                    },
                    TensorF32 {
                        data: &params,
                        dims: vec![3],
                    },
                ],
            )
            .unwrap();
        let (mean, var) = (&outs[0], &outs[1]);

        // native posterior
        let op = native_op(&x, kind, &params);
        let y64: Vec<f64> = y.iter().map(|&v| v as f64).collect();
        let ch = bbmm_gp::linalg::cholesky::Cholesky::new_with_jitter(
            &bbmm_gp::linalg::op::LinearOp::dense(&op),
        )
        .unwrap();
        let xs64 = Mat::from_vec(m, D, xs.iter().map(|&v| v as f64).collect());
        let k_star = op.cross(&xs64, op.x());
        let diag: Vec<f64> = (0..m)
            .map(|i| op.kernel().eval(xs64.row(i), xs64.row(i)))
            .collect();
        let native = bbmm_gp::gp::predict::predict(&k_star, &diag, |mm| ch.solve_mat(mm), &y64);
        for i in 0..m {
            assert!(
                (mean[i] as f64 - native.mean[i]).abs() < 5e-3,
                "{kind} mean[{i}] {} vs {}",
                mean[i],
                native.mean[i]
            );
            assert!(
                (var[i] as f64 - native.var[i]).abs() < 5e-3,
                "{kind} var[{i}]"
            );
        }
    }
}

#[test]
fn kernel_matmul_artifact_matches_native_fused_matmul() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let name = format!("kernel_matmul_rbf_n{N}_d{D}_t{T}");
    if !rt.artifact_exists(&name) {
        eprintln!("SKIP {name}");
        return;
    }
    rt.load(&name).unwrap();
    let (x, _y, _z) = problem(10);
    let mut rng = Rng::new(11);
    let mut v = vec![0f32; N * T];
    for q in v.iter_mut() {
        *q = rng.normal() as f32;
    }
    let params = [-0.5f32, 0.0, -2.0];
    let outs = rt
        .execute_f32(
            &name,
            &[
                TensorF32 {
                    data: &x,
                    dims: vec![N as i64, D as i64],
                },
                TensorF32 {
                    data: &v,
                    dims: vec![N as i64, T as i64],
                },
                TensorF32 {
                    data: &params,
                    dims: vec![3],
                },
            ],
        )
        .unwrap();
    let got = &outs[0];
    // native (Rust) fused kernel matmul — the same operation at L3
    let op = native_op(&x, "rbf", &params);
    let v64 = Mat::from_vec(N, T, v.iter().map(|&q| q as f64).collect());
    let want = bbmm_gp::linalg::op::LinearOp::matmul(&op, &v64);
    let mut max_diff = 0.0f64;
    for i in 0..N {
        for c in 0..T {
            max_diff = max_diff.max((got[i * T + c] as f64 - want.get(i, c)).abs());
        }
    }
    assert!(max_diff < 1e-3, "L1 Pallas vs L3 Rust fused matmul: {max_diff}");
}
