//! Multi-tenant serving integration: a two-tenant deployment served over
//! TCP (`bbmm serve`'s accept loop) must answer interleaved per-tenant
//! requests correctly **through one `BatchOp` solve path per tick**, with
//! per-tenant solve plans cached across predict calls.

use bbmm_gp::coordinator::{
    multi_served_predictor, serve, BatchPolicy, DynamicBatcher, ServableModel, ServerConfig,
    TenantSpec,
};
use bbmm_gp::kernels::{DenseKernelOp, Matern52, Rbf};
use bbmm_gp::linalg::cholesky::Cholesky;
use bbmm_gp::linalg::op::{LinearOp, SolveOptions, SolvePlanCache};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::Rng;
use std::io::{BufRead, BufReader, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// An exact-GP posterior behind the serving seam (what `bbmm serve`
/// builds per tenant).
struct ExactTenant {
    op: DenseKernelOp,
    y: Vec<f64>,
}

impl ServableModel for ExactTenant {
    fn op(&self) -> &dyn LinearOp {
        &self.op
    }
    fn cross(&self, xs: &Mat) -> Mat {
        self.op.cross(xs, self.op.x())
    }
    fn prior_diag(&self, xs: &Mat) -> Vec<f64> {
        (0..xs.rows())
            .map(|i| self.op.kernel().eval(xs.row(i), xs.row(i)))
            .collect()
    }
    fn y(&self) -> &[f64] {
        &self.y
    }
}

fn tenant(n: usize, seed: u64, matern: bool, noise: f64) -> ExactTenant {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let y: Vec<f64> = (0..n)
        .map(|i| (3.0 * x.get(i, 0)).sin() - 0.5 * x.get(i, 1) + 0.02 * rng.normal())
        .collect();
    let kernel: Box<dyn bbmm_gp::kernels::Kernel> = if matern {
        Box::new(Matern52::new(0.6, 0.9))
    } else {
        Box::new(Rbf::new(0.5, 1.0))
    };
    ExactTenant {
        op: DenseKernelOp::new(x, kernel, noise),
        y,
    }
}

/// Dense-Cholesky reference posterior mean for one tenant at one point.
fn reference_mean(t: &ExactTenant, x: &[f64]) -> f64 {
    let kd = t.op.dense();
    let alpha = Cholesky::new_with_jitter(&kd).unwrap().solve_vec(&t.y);
    let xs = Mat::from_vec(1, 2, x.to_vec());
    let k_star = t.op.cross(&xs, t.op.x());
    k_star.row(0).iter().zip(alpha.iter()).map(|(a, b)| a * b).sum()
}

#[test]
fn two_tenant_deployment_answers_interleaved_requests_through_one_batch_path() {
    let n = 60;
    let ta = tenant(n, 1, false, 0.05);
    let tb = tenant(n, 2, true, 0.2);
    // references computed against the same operators before they move
    // into the server
    let probe_a = [0.25, -0.5];
    let probe_b = [-0.75, 0.1];
    let want_a = reference_mean(&ta, &probe_a);
    let want_b = reference_mean(&tb, &probe_b);

    let opts = SolveOptions {
        max_iters: 400,
        tol: 1e-10,
        precond_rank: 5,
    };
    let cache = Arc::new(SolvePlanCache::new());
    let models: Vec<(String, Box<dyn ServableModel>)> = vec![
        ("alpha".to_string(), Box::new(ta)),
        ("beta".to_string(), Box::new(tb)),
    ];
    let predictor = multi_served_predictor(models, opts, Arc::clone(&cache));
    let batcher = Arc::new(DynamicBatcher::new_multi(
        vec![TenantSpec::new("alpha", 2), TenantSpec::new("beta", 2)],
        BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_millis(25),
            ..BatchPolicy::default()
        },
        predictor,
    ));

    let stop = Arc::new(AtomicBool::new(false));
    let config = ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        operator: "alpha=exact(rbf) | beta=exact(matern52)".to_string(),
        shard_count: 1,
        stop: Arc::clone(&stop),
    };
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let srv = {
        let b = Arc::clone(&batcher);
        std::thread::spawn(move || {
            serve(config, b, move |addr| {
                addr_tx.send(addr).unwrap();
            })
            .unwrap();
        })
    };
    let addr = addr_rx.recv().unwrap();

    // two concurrent clients interleave tenants so ticks carry BOTH
    // tenants' blocks — each tick is then one BatchOp dispatch
    let mut clients = Vec::new();
    for c in 0..2 {
        let line = if c == 0 {
            format!("alpha:{},{}\n", probe_a[0], probe_a[1])
        } else {
            format!("beta:{},{}\n", probe_b[0], probe_b[1])
        };
        clients.push(std::thread::spawn(move || {
            let mut conn = std::net::TcpStream::connect(addr).unwrap();
            let mut means = Vec::new();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            for _ in 0..4 {
                conn.write_all(line.as_bytes()).unwrap();
                let mut resp = String::new();
                reader.read_line(&mut resp).unwrap();
                assert!(!resp.starts_with("ERR"), "{resp}");
                let mean: f64 = resp.trim().split(',').next().unwrap().parse().unwrap();
                means.push(mean);
            }
            means
        }));
    }
    let mean_a = clients.remove(0).join().unwrap();
    let mean_b = clients.remove(0).join().unwrap();
    for m in &mean_a {
        assert!((m - want_a).abs() < 1e-5, "alpha: {m} vs {want_a}");
    }
    for m in &mean_b {
        assert!((m - want_b).abs() < 1e-5, "beta: {m} vs {want_b}");
    }

    // protocol surface: tenant listing + stats + unknown tenant
    let mut conn = std::net::TcpStream::connect(addr).unwrap();
    conn.write_all(b"TENANTS\nghost:1.0,2.0\nSTATS\nQUIT\n").unwrap();
    let mut lines = BufReader::new(conn.try_clone().unwrap()).lines();
    assert_eq!(lines.next().unwrap().unwrap(), "alpha:2 beta:2");
    assert!(lines.next().unwrap().unwrap().starts_with("ERR unknown tenant"));
    let stats = lines.next().unwrap().unwrap();
    assert!(stats.contains("requests=8"), "{stats}");
    assert_eq!(lines.next().unwrap().unwrap(), "BYE");

    stop.store(true, Ordering::Relaxed);
    srv.join().unwrap();

    // per-tenant plans were built exactly once each and then reused
    // across predict calls (8 requests over ≥1 ticks)
    assert_eq!(cache.misses(), 2, "{}", cache.stats());
    assert_eq!(cache.invalidations(), 0);
    assert!(cache.hits() >= 2, "{}", cache.stats());
    assert_eq!(cache.len(), 2);
    // coalescing actually happened: fewer ticks than requests
    let batches = batcher.metrics.batches.load(Ordering::Relaxed);
    assert!(batches < 8, "batches={batches}");
}
