//! Batched-training parity: [`BatchBbmmEngine`] must reproduce the
//! sequential per-candidate [`BbmmEngine`] **exactly** (shared probe RNG
//! stream ⇒ element i of one batched call equals the i-th sequential call
//! on an identically seeded scalar engine), while paying measurably fewer
//! covariance operator passes on the shared-covariance fast path — the
//! acceptance bar of the batched-sweep tentpole.

use bbmm_gp::gp::exact::{Engine, ExactGp};
use bbmm_gp::gp::mll::{
    mll_and_grad_batch_with, BatchBbmmEngine, BatchInferenceEngine, BbmmEngine, InferenceEngine,
};
use bbmm_gp::gp::{SgprModel, SgprOp};
use bbmm_gp::kernels::{DenseKernelOp, Kernel, KernelCovOp, Rbf};
use bbmm_gp::linalg::op::{AddedDiagOp, BatchOp, LinearOp};
use bbmm_gp::tensor::Mat;
use bbmm_gp::train::{noise_grid_inits, CandidateStatus, TrainConfig};
use bbmm_gp::util::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};

fn dataset(n: usize, seed: u64) -> (Mat, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let y: Vec<f64> = (0..n)
        .map(|i| {
            let r = x.row(i);
            (3.0 * r[0]).sin() + 0.5 * r[1] + 0.05 * rng.normal()
        })
        .collect();
    (x, y)
}

fn assert_mll_parity(
    got: &bbmm_gp::gp::MllGrad,
    want: &bbmm_gp::gp::MllGrad,
    tol: f64,
    label: &str,
) {
    assert_eq!(got.iterations, want.iterations, "{label}: iterations");
    assert!(
        (got.nmll - want.nmll).abs() < tol,
        "{label}: nmll {} vs {}",
        got.nmll,
        want.nmll
    );
    assert!((got.datafit - want.datafit).abs() < tol, "{label}: datafit");
    assert!((got.logdet - want.logdet).abs() < tol, "{label}: logdet");
    assert_eq!(got.grad.len(), want.grad.len(), "{label}: grad length");
    for (p, (g, w)) in got.grad.iter().zip(want.grad.iter()).enumerate() {
        assert!((g - w).abs() < tol, "{label}: grad[{p}] {g} vs {w}");
    }
}

#[test]
fn batched_engine_matches_sequential_engine_on_shared_covariance() {
    // noise sweep over one covariance: the fused fast path end to end
    let (x, y) = dataset(45, 1);
    let cov = KernelCovOp::new(x, Box::new(Rbf::new(0.5, 1.0)));
    let sigma2s = vec![0.05, 0.3, 1.1, 0.6];
    let batch = BatchOp::shared(&cov, sigma2s.clone());
    let mut batched = BatchBbmmEngine::new(45, 8, 4, 7);
    let got = batched.mll_and_grad_batch(&batch, &y);
    assert_eq!(got.len(), 4);
    // the sequential reference: ONE scalar engine with the same seed,
    // driven candidate-by-candidate through the sequential-baseline
    // helper (the shared-RNG parity contract)
    let mut seq = BbmmEngine::new(45, 8, 4, 7);
    let want = mll_and_grad_batch_with(&mut seq, &batch, &y);
    for k in 0..sigma2s.len() {
        assert_mll_parity(&got[k], &want[k], 1e-10, &format!("shared candidate {k}"));
    }
    // the engine's accounting shows the batching: one fused product per
    // shared iteration vs the per-system sum a loop would pay
    assert!(
        batched.last_stats.batched_products < batched.last_stats.system_iterations,
        "stats {:?}",
        batched.last_stats
    );
}

#[test]
fn batched_engine_matches_sequential_engine_on_distinct_candidates() {
    // general path: every candidate has its own kernel hyperparameters
    let (x, y) = dataset(40, 2);
    let raws = [
        vec![(0.4f64).ln(), (0.9f64).ln(), (0.05f64).ln()],
        vec![(0.7f64).ln(), (1.3f64).ln(), (0.25f64).ln()],
        vec![(1.5f64).ln(), (0.6f64).ln(), (0.80f64).ln()],
    ];
    let mut ops: Vec<DenseKernelOp> = raws
        .iter()
        .map(|_| DenseKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.1))
        .collect();
    for (op, raw) in ops.iter_mut().zip(&raws) {
        op.set_params(raw);
    }
    let els: Vec<&dyn LinearOp> = ops.iter().map(|o| o as &dyn LinearOp).collect();
    let batch = BatchOp::new(els);
    assert!(!batch.is_shared());
    let mut batched = BatchBbmmEngine::new(40, 6, 5, 99);
    let got = batched.mll_and_grad_batch(&batch, &y);
    let mut seq = BbmmEngine::new(40, 6, 5, 99);
    for (k, op) in ops.iter().enumerate() {
        let want = seq.mll_and_grad(op, &y);
        assert_mll_parity(&got[k], &want, 1e-10, &format!("general candidate {k}"));
    }
}

#[test]
fn batched_engine_matches_sequential_engine_on_sgpr() {
    // SGPR operators keep their custom dmatmul through the batch
    let (x, y) = dataset(50, 3);
    let mut rng = Rng::new(30);
    let u = Mat::from_fn(8, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let raws = [
        vec![(0.5f64).ln(), (1.0f64).ln(), (0.10f64).ln()],
        vec![(0.8f64).ln(), (0.7f64).ln(), (0.30f64).ln()],
        vec![(0.3f64).ln(), (1.4f64).ln(), (0.06f64).ln()],
    ];
    let mut ops: Vec<SgprOp> = raws
        .iter()
        .map(|_| SgprOp::new(x.clone(), u.clone(), Box::new(Rbf::new(0.5, 1.0)), 0.1))
        .collect();
    for (op, raw) in ops.iter_mut().zip(&raws) {
        op.set_params(raw);
    }
    let els: Vec<&dyn LinearOp> = ops.iter().map(|o| o as &dyn LinearOp).collect();
    let batch = BatchOp::new(els);
    let mut batched = BatchBbmmEngine::new(60, 6, 3, 11);
    let got = batched.mll_and_grad_batch(&batch, &y);
    let mut seq = BbmmEngine::new(60, 6, 3, 11);
    for (k, op) in ops.iter().enumerate() {
        let want = seq.mll_and_grad(op, &y);
        assert_eq!(got[k].grad.len(), op.n_params(), "sgpr grad arity");
        assert_mll_parity(&got[k], &want, 1e-10, &format!("sgpr candidate {k}"));
    }
}

#[test]
fn per_candidate_early_stopping_shows_in_iteration_counts() {
    // a heavy-noise (well-conditioned) candidate must freeze earlier than
    // a near-noiseless one inside the same batched call
    let (x, y) = dataset(60, 4);
    let cov = KernelCovOp::new(x, Box::new(Rbf::new(0.4, 1.0)));
    let batch = BatchOp::shared(&cov, vec![25.0, 1e-4]);
    let mut engine = BatchBbmmEngine::new(120, 4, 0, 5);
    let got = engine.mll_and_grad_batch(&batch, &y);
    assert!(
        got[0].iterations < got[1].iterations,
        "easy {} !< hard {}",
        got[0].iterations,
        got[1].iterations
    );
}

/// Covariance wrapper that counts every operator pass (`matmul` +
/// `dmatmul`) — the observable behind "fewer total covariance matmul
/// passes".
struct CountingCov {
    inner: KernelCovOp,
    calls: AtomicUsize,
}

impl LinearOp for CountingCov {
    fn shape(&self) -> (usize, usize) {
        self.inner.shape()
    }
    fn n_params(&self) -> usize {
        LinearOp::n_params(&self.inner)
    }
    fn matmul(&self, m: &Mat) -> Mat {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.inner.matmul(m)
    }
    fn dmatmul(&self, param: usize, m: &Mat) -> Mat {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.inner.dmatmul(param, m)
    }
    fn diag(&self) -> Vec<f64> {
        self.inner.diag()
    }
    fn row(&self, i: usize) -> Vec<f64> {
        self.inner.row(i)
    }
    fn entry(&self, i: usize, j: usize) -> f64 {
        self.inner.entry(i, j)
    }
}

#[test]
fn shared_sweep_pays_fewer_covariance_passes_than_a_sequential_loop() {
    let (x, y) = dataset(40, 5);
    let counting = CountingCov {
        inner: KernelCovOp::new(x, Box::new(Rbf::new(0.5, 1.0))),
        calls: AtomicUsize::new(0),
    };
    let b = 8;
    let sigma2s: Vec<f64> = (0..b).map(|i| 0.05 * (1.0 + i as f64)).collect();

    let batch = BatchOp::shared(&counting, sigma2s.clone());
    let mut batched = BatchBbmmEngine::new(15, 4, 0, 3);
    let got = batched.mll_and_grad_batch(&batch, &y);
    let batched_calls = counting.calls.swap(0, Ordering::Relaxed);

    let mut seq = BbmmEngine::new(15, 4, 0, 3);
    let mut want = Vec::new();
    for &s2 in &sigma2s {
        let op = AddedDiagOp::new(&counting, s2);
        want.push(seq.mll_and_grad(&op, &y));
    }
    let sequential_calls = counting.calls.load(Ordering::Relaxed);

    // numerics identical…
    for k in 0..b {
        assert_mll_parity(&got[k], &want[k], 1e-10, &format!("counted candidate {k}"));
    }
    // …at a fraction of the covariance passes (solve iterations fuse into
    // one product per shared iteration; gradient passes fuse per param)
    assert!(
        batched_calls * 2 <= sequential_calls,
        "batched {batched_calls} passes vs sequential {sequential_calls}"
    );
}

#[test]
fn exact_fit_sweep_trains_lockstep_and_picks_a_winner() {
    let (x, y) = dataset(60, 8);
    let kernel = Rbf::new(0.5, 1.0);
    let mut template = Kernel::params(&kernel);
    template.push((0.1f64).ln());
    let inits = noise_grid_inits(&template, &[0.02, 0.1, 0.5]);
    let mut engine = BatchBbmmEngine::new(60, 8, 5, 13);
    let report = ExactGp::fit_sweep(
        &x,
        &y,
        &kernel,
        &inits,
        &mut engine,
        TrainConfig {
            iters: 12,
            lr: 0.1,
            ..Default::default()
        },
    );
    let bi = report.best.expect("sweep must produce a winner");
    let winner = &report.candidates[bi];
    assert!(winner.best_nmll.is_finite());
    assert!(!winner.history.is_empty());
    assert!(
        winner.history[0].nmll >= winner.best_nmll - 1e-9,
        "training must not regress below the recorded best"
    );
    for c in &report.candidates {
        assert_ne!(c.status, CandidateStatus::Diverged, "healthy data must not diverge");
        assert_eq!(c.params.len(), 3);
    }
    // the winning hyperparameters materialise into a predictive model
    let gp = ExactGp::from_sweep(x.clone(), y.clone(), &kernel, &report, Engine::Cholesky);
    assert!(gp.is_some());
}

#[test]
fn sgpr_fit_sweep_runs_end_to_end() {
    let (x, y) = dataset(70, 9);
    let mut rng = Rng::new(90);
    let u = Mat::from_fn(10, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let kernel = Rbf::new(0.5, 1.0);
    let mut template = Kernel::params(&kernel);
    template.push((0.1f64).ln());
    let inits = vec![template.clone(), {
        let mut p = template.clone();
        p[0] += 0.5;
        p[2] = (0.4f64).ln();
        p
    }];
    let mut engine = BatchBbmmEngine::new(50, 6, 3, 17);
    let report = SgprModel::fit_sweep(
        &x,
        &y,
        &u,
        &kernel,
        &inits,
        &mut engine,
        TrainConfig {
            iters: 8,
            lr: 0.1,
            ..Default::default()
        },
    );
    let bi = report.best.expect("sgpr sweep must produce a winner");
    assert!(report.candidates[bi].best_nmll.is_finite());
    assert_eq!(report.candidates.len(), 2);
    for c in &report.candidates {
        assert!(!c.history.is_empty());
        // every recorded gradient has SGPR's full arity (custom dmatmul
        // survived the batch — the single-active-candidate case included)
        assert_eq!(c.params.len(), 3);
    }
}
