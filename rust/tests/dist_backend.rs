//! Placement-parity and fault-tolerance tests for the distributed shard
//! backends: multi-process workers must reproduce the in-process operators
//! (products to 1e-10, GP training/prediction to 1e-8), a worker killed
//! mid-solve must be respawned without changing the final answer, the
//! heartbeat must resurrect dead slots, and the out-of-core spool must
//! round-trip checkpointed panels under a budget smaller than one shard.
//!
//! Worker processes are forked from the `bbmm` binary Cargo builds for
//! this test run (`CARGO_BIN_EXE_bbmm`), exercising the real
//! `bbmm shard-worker --connect` entry point and wire protocol.

use bbmm_gp::gp::exact::{Engine, ExactGp};
use bbmm_gp::gp::mll::BbmmEngine;
use bbmm_gp::gp::sgpr::SgprOp;
use bbmm_gp::kernels::{KernelCov, Matern32, Rbf, ShardedCovOp, ShardedKernelOp};
use bbmm_gp::linalg::mbcg::{mbcg_op, MbcgOptions};
use bbmm_gp::linalg::op::{plan_batch, solve_batch, BatchOp, LinearOp, SolveOptions, SolvePlan};
use bbmm_gp::runtime::dist::{MultiProcessBackend, OutOfCoreBackend, ShardBackend, WorkerLaunch};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::Rng;
use std::cell::Cell;
use std::sync::Arc;

/// A smooth regression problem: inputs in [-1.5, 1.5]², targets a noisy
/// wave, plus a held-out query grid.
fn dataset(n: usize, seed: u64) -> (Mat, Vec<f64>, Mat) {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.5, 1.5));
    let y: Vec<f64> = (0..n)
        .map(|i| (2.0 * x.get(i, 0)).sin() + 0.5 * x.get(i, 1).cos() + 0.05 * rng.normal())
        .collect();
    let xt = Mat::from_fn(40, 2, |_, _| rng.uniform_in(-1.5, 1.5));
    (x, y, xt)
}

/// Fork workers from the `bbmm` binary built for this test profile.
fn worker_launch() -> WorkerLaunch {
    WorkerLaunch {
        exe: env!("CARGO_BIN_EXE_bbmm").into(),
        ..WorkerLaunch::default()
    }
}

fn rel_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut scale = 1.0f64;
    let mut diff = 0.0f64;
    for (p, q) in a.iter().zip(b) {
        scale = scale.max(q.abs());
        diff = diff.max((p - q).abs());
    }
    diff / scale
}

/// Raw operator parity: value and derivative products routed through
/// worker processes match the in-process sharded operator, before and
/// after a hyperparameter push over the wire.
#[test]
fn proc_backend_products_match_inprocess() {
    let n = 150;
    let (x, _y, _xt) = dataset(n, 3);
    let mut rng = Rng::new(4);
    let m = Mat::from_fn(n, 5, |_, _| rng.normal());
    let kernel = Rbf::new(0.7, 1.1);
    let mut inproc = ShardedCovOp::new(x.clone(), Box::new(Rbf::new(0.7, 1.1)), 6);
    let proc = MultiProcessBackend::launch(x.clone(), &kernel, 0.0, 6, 2, 4, worker_launch())
        .expect("fork shard workers");
    assert_eq!(proc.workers(), 2);
    let mut routed = ShardedCovOp::new(x, Box::new(Rbf::new(0.7, 1.1)), 6)
        .with_backend(Arc::new(proc));

    let check = |routed: &ShardedCovOp, inproc: &ShardedCovOp, tag: &str| {
        let want = inproc.matmul(&m);
        let scale = want.fro_norm().max(1.0);
        let diff = routed.matmul(&m).max_abs_diff(&want) / scale;
        assert!(diff < 1e-10, "{tag} value product: rel diff {diff}");
        for p in 0..inproc.n_params() {
            let want_d = inproc.dmatmul(p, &m);
            let dscale = want_d.fro_norm().max(1.0);
            let ddiff = routed.dmatmul(p, &m).max_abs_diff(&want_d) / dscale;
            assert!(ddiff < 1e-10, "{tag} dmatmul({p}): rel diff {ddiff}");
        }
    };
    check(&routed, &inproc, "initial params");

    // push new hyperparameters to the workers and re-check every product
    let mut raw = inproc.kernel().params();
    raw[0] += 0.3;
    raw[1] -= 0.2;
    inproc.set_kernel_params(&raw);
    routed.set_kernel_params(&raw);
    check(&routed, &inproc, "updated params");

    let stats = routed.backend().unwrap().stats();
    assert!(stats.rounds >= 6, "expected ≥6 round trips, saw {}", stats.rounds);
    assert!(stats.bytes_tx > 0 && stats.bytes_rx > 0);
    assert_eq!(stats.restarts, 0, "no worker should have crashed");
}

/// End-to-end GP parity: training (mll + gradients) and prediction over a
/// process-parallel covariance agree with the in-process placement to
/// 1e-8 relative at fixed seeds.
#[test]
fn proc_exact_gp_matches_inprocess_training_and_prediction() {
    let (x, y, xt) = dataset(220, 11);
    let noise = 0.05;
    let engine = || Engine::Bbmm(BbmmEngine::new(150, 8, 8, 42));
    let mut reference = ExactGp::over(
        Box::new(ShardedCovOp::new(x.clone(), Box::new(Matern32::new(0.6, 1.0)), 5)),
        y.clone(),
        noise,
        engine(),
    );
    let kernel = Matern32::new(0.6, 1.0);
    let proc = MultiProcessBackend::launch(x.clone(), &kernel, noise, 5, 2, 4, worker_launch())
        .expect("fork shard workers");
    let routed = ShardedCovOp::new(x, Box::new(Matern32::new(0.6, 1.0)), 5)
        .with_backend(Arc::new(proc));
    let mut distributed = ExactGp::over(Box::new(routed), y, noise, engine());

    let g_ref = reference.mll_and_grad();
    let g_dist = distributed.mll_and_grad();
    let mll_diff = (g_dist.nmll - g_ref.nmll).abs() / g_ref.nmll.abs().max(1.0);
    assert!(mll_diff < 1e-8, "nmll rel diff {mll_diff}");
    let grad_diff = rel_diff(&g_dist.grad, &g_ref.grad);
    assert!(grad_diff < 1e-8, "gradient rel diff {grad_diff}");

    let p_ref = reference.predict(&xt);
    let p_dist = distributed.predict(&xt);
    let mean_diff = rel_diff(&p_dist.mean, &p_ref.mean);
    let var_diff = rel_diff(&p_dist.var, &p_ref.var);
    assert!(mean_diff < 1e-8, "predictive mean rel diff {mean_diff}");
    assert!(var_diff < 1e-8, "predictive variance rel diff {var_diff}");
}

/// SIGKILL one worker in the middle of an mBCG solve (from inside the
/// per-iteration preconditioner hook, so the timing is deterministic):
/// the dispatcher must respawn it, replay its shards, and produce the
/// bit-identical answer a crash-free run of the same backend produces.
#[test]
fn worker_crash_mid_solve_recovers_and_preserves_the_answer() {
    let n = 160;
    let (x, _y, _xt) = dataset(n, 21);
    let mut rng = Rng::new(22);
    let b = Mat::from_fn(n, 3, |_, _| rng.normal());
    let kernel = Rbf::new(0.6, 1.0);
    let inproc = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.6, 1.0)), 0.25, 4);
    // heartbeat disabled: recovery must come from the product path itself
    let proc = Arc::new(
        MultiProcessBackend::launch(
            x.clone(),
            &kernel,
            0.25,
            4,
            2,
            4,
            WorkerLaunch {
                heartbeat_ms: 0,
                ..worker_launch()
            },
        )
        .expect("fork shard workers"),
    );
    let routed = ShardedKernelOp::new(x, Box::new(Rbf::new(0.6, 1.0)), 0.25, 4)
        .with_backend(proc.clone() as Arc<dyn ShardBackend>);
    let opts = MbcgOptions {
        max_iters: 20,
        tol: 0.0,
        n_solve_only: usize::MAX,
    };
    // crash-free run of the same backend: the determinism baseline
    let want = mbcg_op(&routed, &b, |r| r.clone(), &opts);
    let calls = Cell::new(0usize);
    let got = mbcg_op(
        &routed,
        &b,
        |r| {
            calls.set(calls.get() + 1);
            if calls.get() == 3 {
                proc.kill_worker(0);
            }
            r.clone()
        },
        &opts,
    );
    assert!(calls.get() > 3, "the kill must land mid-solve");
    assert_eq!(got.iterations, want.iterations);
    assert!(
        got.solves.max_abs_diff(&want.solves) == 0.0,
        "crash recovery changed the solve: diff {}",
        got.solves.max_abs_diff(&want.solves)
    );
    assert!(proc.stats().restarts >= 1, "the killed worker was never respawned");
    // and the distributed answer is still the in-process answer
    let reference = mbcg_op(&inproc, &b, |r| r.clone(), &opts);
    let scale = reference.solves.fro_norm().max(1.0);
    let diff = got.solves.max_abs_diff(&reference.solves) / scale;
    assert!(diff < 1e-8, "in-process parity after recovery: {diff}");
}

/// The background heartbeat notices a killed worker and respawns it even
/// when no product is in flight.
#[test]
fn ping_all_respawns_killed_workers() {
    let (x, _y, _xt) = dataset(60, 51);
    let kernel = Rbf::new(0.6, 1.0);
    let proc = MultiProcessBackend::launch(
        x,
        &kernel,
        0.1,
        4,
        2,
        4,
        WorkerLaunch {
            heartbeat_ms: 0, // drive the monitor by hand for determinism
            ..worker_launch()
        },
    )
    .expect("fork shard workers");
    assert_eq!(proc.ping_all(), 2);
    proc.kill_worker(0);
    assert_eq!(proc.ping_all(), 2, "heartbeat must respawn the dead slot");
    assert!(proc.stats().restarts >= 1);
    proc.shutdown();
}

/// Heterogeneous serving batch — an SGPR (direct Woodbury) element next
/// to a process-parallel sharded element — planned and solved through the
/// same dispatcher, matching the all-in-process batch.
#[test]
fn mixed_sgpr_and_proc_sharded_batch_solves_match_inprocess() {
    let n = 140;
    let (x, _y, _xt) = dataset(n, 31);
    let mut rng = Rng::new(32);
    let u = Mat::from_fn(15, 2, |_, _| rng.uniform_in(-1.5, 1.5));
    let sgpr = SgprOp::new(x.clone(), u, Box::new(Rbf::new(0.8, 1.0)), 0.05);
    let kernel = Rbf::new(0.5, 0.9);
    let inproc = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 0.9)), 0.25, 4);
    let proc = MultiProcessBackend::launch(x.clone(), &kernel, 0.25, 4, 2, 4, worker_launch())
        .expect("fork shard workers");
    let routed = ShardedKernelOp::new(x, Box::new(Rbf::new(0.5, 0.9)), 0.25, 4)
        .with_backend(Arc::new(proc));
    let bs: Vec<Mat> = (0..2)
        .map(|_| Mat::from_fn(n, 2, |_, _| rng.normal()))
        .collect();
    let b_refs: Vec<&Mat> = bs.iter().collect();
    let opts = SolveOptions {
        max_iters: 400,
        tol: 1e-12,
        ..SolveOptions::default()
    };
    let solve_pair = |second: &dyn LinearOp| {
        let batch = BatchOp::new(vec![&sgpr as &dyn LinearOp, second]);
        let plans = plan_batch(&batch, &opts);
        let plan_refs: Vec<&SolvePlan> = plans.iter().collect();
        solve_batch(&batch, &plan_refs, &b_refs, &opts)
    };
    let want = solve_pair(&inproc);
    let got = solve_pair(&routed);
    for (i, (a, c)) in got.iter().zip(want.iter()).enumerate() {
        let scale = c.fro_norm().max(1.0);
        let diff = a.max_abs_diff(c) / scale;
        assert!(diff < 1e-8, "batch element {i}: rel diff {diff}");
    }
}

/// Out-of-core round-trip: panels checkpointed to the spool under a
/// window budget smaller than one shard must reproduce in-process
/// training and prediction, and the spool must vanish on shutdown.
#[test]
fn ooc_backend_spools_panels_and_matches_inprocess() {
    let n = 180;
    let shards = 6;
    let (x, y, xt) = dataset(n, 41);
    let noise = 0.05;
    let engine = || Engine::Bbmm(BbmmEngine::new(150, 8, 8, 7));
    let mut reference = ExactGp::over(
        Box::new(ShardedCovOp::new(x.clone(), Box::new(Rbf::new(0.6, 1.0)), shards)),
        y.clone(),
        noise,
        engine(),
    );
    let spool_op = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.6, 1.0)), noise, shards);
    let ooc = OutOfCoreBackend::new(spool_op, 16 * 1024).expect("spool panels");
    assert!(
        ooc.window_rows() < n / shards,
        "budget must force chunked panel streaming (window {} rows)",
        ooc.window_rows()
    );
    let dir = ooc.spool_dir().clone();
    assert!(dir.is_dir(), "spool directory missing");
    let routed = ShardedCovOp::new(x, Box::new(Rbf::new(0.6, 1.0)), shards)
        .with_backend(Arc::new(ooc));
    let mut out_of_core = ExactGp::over(Box::new(routed), y, noise, engine());

    let g_ref = reference.mll_and_grad();
    let g_ooc = out_of_core.mll_and_grad();
    let mll_diff = (g_ooc.nmll - g_ref.nmll).abs() / g_ref.nmll.abs().max(1.0);
    assert!(mll_diff < 1e-8, "nmll rel diff {mll_diff}");
    assert!(rel_diff(&g_ooc.grad, &g_ref.grad) < 1e-8);
    let p_ref = reference.predict(&xt);
    let p_ooc = out_of_core.predict(&xt);
    assert!(rel_diff(&p_ooc.mean, &p_ref.mean) < 1e-8);
    assert!(rel_diff(&p_ooc.var, &p_ref.var) < 1e-8);

    drop(out_of_core); // drops the last backend handle → shutdown
    assert!(!dir.exists(), "shutdown must remove the spool directory");
}
