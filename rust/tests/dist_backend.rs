//! Placement-parity and fault-tolerance tests for the distributed shard
//! backends: multi-process workers must reproduce the in-process operators
//! (products to 1e-10, GP training/prediction to 1e-8), a worker killed
//! mid-solve must be respawned without changing the final answer, the
//! heartbeat must resurrect dead slots, and the out-of-core spool must
//! round-trip checkpointed panels under a budget smaller than one shard.
//! The shared-memory data plane must reproduce the TCP transport's
//! answers while moving **zero** payload bytes through the socket, must
//! survive a mid-solve SIGKILL bit-identically without dropping to TCP,
//! and must degrade to the TCP transport when its segment cannot map.
//!
//! Worker processes are forked from the `bbmm` binary Cargo builds for
//! this test run (`CARGO_BIN_EXE_bbmm`), exercising the real
//! `bbmm shard-worker --connect` entry point and wire protocol.

use bbmm_gp::gp::exact::{Engine, ExactGp};
use bbmm_gp::gp::mll::BbmmEngine;
use bbmm_gp::gp::sgpr::SgprOp;
use bbmm_gp::kernels::{KernelCov, Matern32, Rbf, ShardBlock, ShardedCovOp, ShardedKernelOp};
use bbmm_gp::linalg::mbcg::{mbcg_op, MbcgOptions};
use bbmm_gp::linalg::op::{plan_batch, solve_batch, BatchOp, LinearOp, SolveOptions, SolvePlan};
use bbmm_gp::runtime::dist::{
    MultiProcessBackend, NumaMode, OutOfCoreBackend, ShardBackend, ShmOptions, Transport,
    WorkerLaunch,
};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::Rng;
use std::cell::Cell;
use std::sync::Arc;

/// A smooth regression problem: inputs in [-1.5, 1.5]², targets a noisy
/// wave, plus a held-out query grid.
fn dataset(n: usize, seed: u64) -> (Mat, Vec<f64>, Mat) {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.5, 1.5));
    let y: Vec<f64> = (0..n)
        .map(|i| (2.0 * x.get(i, 0)).sin() + 0.5 * x.get(i, 1).cos() + 0.05 * rng.normal())
        .collect();
    let xt = Mat::from_fn(40, 2, |_, _| rng.uniform_in(-1.5, 1.5));
    (x, y, xt)
}

/// Fork workers from the `bbmm` binary built for this test profile.
fn worker_launch() -> WorkerLaunch {
    WorkerLaunch {
        exe: env!("CARGO_BIN_EXE_bbmm").into(),
        ..WorkerLaunch::default()
    }
}

fn rel_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut scale = 1.0f64;
    let mut diff = 0.0f64;
    for (p, q) in a.iter().zip(b) {
        scale = scale.max(q.abs());
        diff = diff.max((p - q).abs());
    }
    diff / scale
}

/// Raw operator parity: value and derivative products routed through
/// worker processes match the in-process sharded operator, before and
/// after a hyperparameter push over the wire.
#[test]
fn proc_backend_products_match_inprocess() {
    let n = 150;
    let (x, _y, _xt) = dataset(n, 3);
    let mut rng = Rng::new(4);
    let m = Mat::from_fn(n, 5, |_, _| rng.normal());
    let kernel = Rbf::new(0.7, 1.1);
    let mut inproc = ShardedCovOp::new(x.clone(), Box::new(Rbf::new(0.7, 1.1)), 6);
    let proc = MultiProcessBackend::launch(x.clone(), &kernel, 0.0, 6, 2, 4, worker_launch())
        .expect("fork shard workers");
    assert_eq!(proc.workers(), 2);
    let mut routed = ShardedCovOp::new(x, Box::new(Rbf::new(0.7, 1.1)), 6)
        .with_backend(Arc::new(proc));

    let check = |routed: &ShardedCovOp, inproc: &ShardedCovOp, tag: &str| {
        let want = inproc.matmul(&m);
        let scale = want.fro_norm().max(1.0);
        let diff = routed.matmul(&m).max_abs_diff(&want) / scale;
        assert!(diff < 1e-10, "{tag} value product: rel diff {diff}");
        for p in 0..inproc.n_params() {
            let want_d = inproc.dmatmul(p, &m);
            let dscale = want_d.fro_norm().max(1.0);
            let ddiff = routed.dmatmul(p, &m).max_abs_diff(&want_d) / dscale;
            assert!(ddiff < 1e-10, "{tag} dmatmul({p}): rel diff {ddiff}");
        }
    };
    check(&routed, &inproc, "initial params");

    // push new hyperparameters to the workers and re-check every product
    let mut raw = inproc.kernel().params();
    raw[0] += 0.3;
    raw[1] -= 0.2;
    inproc.set_kernel_params(&raw);
    routed.set_kernel_params(&raw);
    check(&routed, &inproc, "updated params");

    let stats = routed.backend().unwrap().stats();
    assert!(stats.rounds >= 6, "expected ≥6 round trips, saw {}", stats.rounds);
    assert!(stats.bytes_tx > 0 && stats.bytes_rx > 0);
    assert_eq!(stats.restarts, 0, "no worker should have crashed");
}

/// End-to-end GP parity: training (mll + gradients) and prediction over a
/// process-parallel covariance agree with the in-process placement to
/// 1e-8 relative at fixed seeds.
#[test]
fn proc_exact_gp_matches_inprocess_training_and_prediction() {
    let (x, y, xt) = dataset(220, 11);
    let noise = 0.05;
    let engine = || Engine::Bbmm(BbmmEngine::new(150, 8, 8, 42));
    let mut reference = ExactGp::over(
        Box::new(ShardedCovOp::new(x.clone(), Box::new(Matern32::new(0.6, 1.0)), 5)),
        y.clone(),
        noise,
        engine(),
    );
    let kernel = Matern32::new(0.6, 1.0);
    let proc = MultiProcessBackend::launch(x.clone(), &kernel, noise, 5, 2, 4, worker_launch())
        .expect("fork shard workers");
    let routed = ShardedCovOp::new(x, Box::new(Matern32::new(0.6, 1.0)), 5)
        .with_backend(Arc::new(proc));
    let mut distributed = ExactGp::over(Box::new(routed), y, noise, engine());

    let g_ref = reference.mll_and_grad();
    let g_dist = distributed.mll_and_grad();
    let mll_diff = (g_dist.nmll - g_ref.nmll).abs() / g_ref.nmll.abs().max(1.0);
    assert!(mll_diff < 1e-8, "nmll rel diff {mll_diff}");
    let grad_diff = rel_diff(&g_dist.grad, &g_ref.grad);
    assert!(grad_diff < 1e-8, "gradient rel diff {grad_diff}");

    let p_ref = reference.predict(&xt);
    let p_dist = distributed.predict(&xt);
    let mean_diff = rel_diff(&p_dist.mean, &p_ref.mean);
    let var_diff = rel_diff(&p_dist.var, &p_ref.var);
    assert!(mean_diff < 1e-8, "predictive mean rel diff {mean_diff}");
    assert!(var_diff < 1e-8, "predictive variance rel diff {var_diff}");
}

/// SIGKILL one worker in the middle of an mBCG solve (from inside the
/// per-iteration preconditioner hook, so the timing is deterministic):
/// the dispatcher must respawn it, replay its shards, and produce the
/// bit-identical answer a crash-free run of the same backend produces.
#[test]
fn worker_crash_mid_solve_recovers_and_preserves_the_answer() {
    let n = 160;
    let (x, _y, _xt) = dataset(n, 21);
    let mut rng = Rng::new(22);
    let b = Mat::from_fn(n, 3, |_, _| rng.normal());
    let kernel = Rbf::new(0.6, 1.0);
    let inproc = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.6, 1.0)), 0.25, 4);
    // heartbeat disabled: recovery must come from the product path itself
    let proc = Arc::new(
        MultiProcessBackend::launch(
            x.clone(),
            &kernel,
            0.25,
            4,
            2,
            4,
            WorkerLaunch {
                heartbeat_ms: 0,
                ..worker_launch()
            },
        )
        .expect("fork shard workers"),
    );
    let routed = ShardedKernelOp::new(x, Box::new(Rbf::new(0.6, 1.0)), 0.25, 4)
        .with_backend(proc.clone() as Arc<dyn ShardBackend>);
    let opts = MbcgOptions {
        max_iters: 20,
        tol: 0.0,
        n_solve_only: usize::MAX,
    };
    // crash-free run of the same backend: the determinism baseline
    let want = mbcg_op(&routed, &b, |r| r.clone(), &opts);
    let calls = Cell::new(0usize);
    let got = mbcg_op(
        &routed,
        &b,
        |r| {
            calls.set(calls.get() + 1);
            if calls.get() == 3 {
                proc.kill_worker(0);
            }
            r.clone()
        },
        &opts,
    );
    assert!(calls.get() > 3, "the kill must land mid-solve");
    assert_eq!(got.iterations, want.iterations);
    assert!(
        got.solves.max_abs_diff(&want.solves) == 0.0,
        "crash recovery changed the solve: diff {}",
        got.solves.max_abs_diff(&want.solves)
    );
    assert!(proc.stats().restarts >= 1, "the killed worker was never respawned");
    // and the distributed answer is still the in-process answer
    let reference = mbcg_op(&inproc, &b, |r| r.clone(), &opts);
    let scale = reference.solves.fro_norm().max(1.0);
    let diff = got.solves.max_abs_diff(&reference.solves) / scale;
    assert!(diff < 1e-8, "in-process parity after recovery: {diff}");
}

/// The background heartbeat notices a killed worker and respawns it even
/// when no product is in flight.
#[test]
fn ping_all_respawns_killed_workers() {
    let (x, _y, _xt) = dataset(60, 51);
    let kernel = Rbf::new(0.6, 1.0);
    let proc = MultiProcessBackend::launch(
        x,
        &kernel,
        0.1,
        4,
        2,
        4,
        WorkerLaunch {
            heartbeat_ms: 0, // drive the monitor by hand for determinism
            ..worker_launch()
        },
    )
    .expect("fork shard workers");
    assert_eq!(proc.ping_all(), 2);
    proc.kill_worker(0);
    assert_eq!(proc.ping_all(), 2, "heartbeat must respawn the dead slot");
    assert!(proc.stats().restarts >= 1);
    proc.shutdown();
}

/// Heterogeneous serving batch — an SGPR (direct Woodbury) element next
/// to a process-parallel sharded element — planned and solved through the
/// same dispatcher, matching the all-in-process batch.
#[test]
fn mixed_sgpr_and_proc_sharded_batch_solves_match_inprocess() {
    let n = 140;
    let (x, _y, _xt) = dataset(n, 31);
    let mut rng = Rng::new(32);
    let u = Mat::from_fn(15, 2, |_, _| rng.uniform_in(-1.5, 1.5));
    let sgpr = SgprOp::new(x.clone(), u, Box::new(Rbf::new(0.8, 1.0)), 0.05);
    let kernel = Rbf::new(0.5, 0.9);
    let inproc = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.5, 0.9)), 0.25, 4);
    let proc = MultiProcessBackend::launch(x.clone(), &kernel, 0.25, 4, 2, 4, worker_launch())
        .expect("fork shard workers");
    let routed = ShardedKernelOp::new(x, Box::new(Rbf::new(0.5, 0.9)), 0.25, 4)
        .with_backend(Arc::new(proc));
    let bs: Vec<Mat> = (0..2)
        .map(|_| Mat::from_fn(n, 2, |_, _| rng.normal()))
        .collect();
    let b_refs: Vec<&Mat> = bs.iter().collect();
    let opts = SolveOptions {
        max_iters: 400,
        tol: 1e-12,
        ..SolveOptions::default()
    };
    let solve_pair = |second: &dyn LinearOp| {
        let batch = BatchOp::new(vec![&sgpr as &dyn LinearOp, second]);
        let plans = plan_batch(&batch, &opts);
        let plan_refs: Vec<&SolvePlan> = plans.iter().collect();
        solve_batch(&batch, &plan_refs, &b_refs, &opts)
    };
    let want = solve_pair(&inproc);
    let got = solve_pair(&routed);
    for (i, (a, c)) in got.iter().zip(want.iter()).enumerate() {
        let scale = c.fro_norm().max(1.0);
        let diff = a.max_abs_diff(c) / scale;
        assert!(diff < 1e-8, "batch element {i}: rel diff {diff}");
    }
}

/// Out-of-core round-trip: panels checkpointed to the spool under a
/// window budget smaller than one shard must reproduce in-process
/// training and prediction, and the spool must vanish on shutdown.
#[test]
fn ooc_backend_spools_panels_and_matches_inprocess() {
    let n = 180;
    let shards = 6;
    let (x, y, xt) = dataset(n, 41);
    let noise = 0.05;
    let engine = || Engine::Bbmm(BbmmEngine::new(150, 8, 8, 7));
    let mut reference = ExactGp::over(
        Box::new(ShardedCovOp::new(x.clone(), Box::new(Rbf::new(0.6, 1.0)), shards)),
        y.clone(),
        noise,
        engine(),
    );
    let spool_op = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.6, 1.0)), noise, shards);
    let ooc = OutOfCoreBackend::new(spool_op, 16 * 1024).expect("spool panels");
    assert!(
        ooc.window_rows() < n / shards,
        "budget must force chunked panel streaming (window {} rows)",
        ooc.window_rows()
    );
    let dir = ooc.spool_dir().clone();
    assert!(dir.is_dir(), "spool directory missing");
    let routed = ShardedCovOp::new(x, Box::new(Rbf::new(0.6, 1.0)), shards)
        .with_backend(Arc::new(ooc));
    let mut out_of_core = ExactGp::over(Box::new(routed), y, noise, engine());

    let g_ref = reference.mll_and_grad();
    let g_ooc = out_of_core.mll_and_grad();
    let mll_diff = (g_ooc.nmll - g_ref.nmll).abs() / g_ref.nmll.abs().max(1.0);
    assert!(mll_diff < 1e-8, "nmll rel diff {mll_diff}");
    assert!(rel_diff(&g_ooc.grad, &g_ref.grad) < 1e-8);
    let p_ref = reference.predict(&xt);
    let p_ooc = out_of_core.predict(&xt);
    assert!(rel_diff(&p_ooc.mean, &p_ref.mean) < 1e-8);
    assert!(rel_diff(&p_ooc.var, &p_ref.var) < 1e-8);

    drop(out_of_core); // drops the last backend handle → shutdown
    assert!(!dir.exists(), "shutdown must remove the spool directory");
}

/// The zero-copy contract: products routed over the shared-memory data
/// plane match the in-process operator to 1e-10 (values and derivatives,
/// before and after a hyperparameter push), and after LoadShard **no
/// payload byte** crosses the socket — `bytes_tx`/`bytes_rx` stay zero
/// while every round is accounted to `shm_rounds` and the control plane
/// (`ctrl_bytes`) keeps ticking.
#[test]
fn shm_backend_products_match_inprocess_with_zero_payload_bytes_on_the_wire() {
    let n = 150;
    let (x, _y, _xt) = dataset(n, 3);
    let mut rng = Rng::new(4);
    let m = Mat::from_fn(n, 5, |_, _| rng.normal());
    let kernel = Rbf::new(0.7, 1.1);
    let mut inproc = ShardedCovOp::new(x.clone(), Box::new(Rbf::new(0.7, 1.1)), 6);
    let proc = Arc::new(
        MultiProcessBackend::launch_with(
            x.clone(),
            &kernel,
            0.0,
            6,
            2,
            4,
            WorkerLaunch {
                heartbeat_ms: 0,
                ..worker_launch()
            },
            Transport::Shm(ShmOptions::default()),
            NumaMode::Auto,
        )
        .expect("fork shard workers over shm"),
    );
    assert!(
        proc.shm_active(),
        "segment should map on this host: {}",
        proc.describe()
    );
    assert!(proc.describe().starts_with("shm:2"), "{}", proc.describe());
    let ctrl_after_load = proc.stats().ctrl_bytes;
    assert!(
        ctrl_after_load > 0,
        "Hello/LoadShard/ShmAttach are control-plane traffic"
    );
    let mut routed = ShardedCovOp::new(x, Box::new(Rbf::new(0.7, 1.1)), 6)
        .with_backend(proc.clone() as Arc<dyn ShardBackend>);

    let check = |routed: &ShardedCovOp, inproc: &ShardedCovOp, tag: &str| {
        let want = inproc.matmul(&m);
        let scale = want.fro_norm().max(1.0);
        let diff = routed.matmul(&m).max_abs_diff(&want) / scale;
        assert!(diff < 1e-10, "{tag} value product: rel diff {diff}");
        for p in 0..inproc.n_params() {
            let want_d = inproc.dmatmul(p, &m);
            let dscale = want_d.fro_norm().max(1.0);
            let ddiff = routed.dmatmul(p, &m).max_abs_diff(&want_d) / dscale;
            assert!(ddiff < 1e-10, "{tag} dmatmul({p}): rel diff {ddiff}");
        }
    };
    check(&routed, &inproc, "initial params");

    let mut raw = inproc.kernel().params();
    raw[0] += 0.3;
    raw[1] -= 0.2;
    inproc.set_kernel_params(&raw);
    routed.set_kernel_params(&raw);
    check(&routed, &inproc, "updated params");

    let stats = proc.stats();
    assert!(stats.rounds >= 6, "expected ≥6 rounds, saw {}", stats.rounds);
    assert_eq!(
        stats.shm_rounds, stats.rounds,
        "every round must ride the shared-memory lane"
    );
    assert_eq!(stats.bytes_tx, 0, "payload leaked onto the socket (tx)");
    assert_eq!(stats.bytes_rx, 0, "payload leaked onto the socket (rx)");
    assert!(
        stats.ctrl_bytes > ctrl_after_load,
        "the SetParams push should ride the control plane"
    );
    assert_eq!(stats.restarts, 0, "no worker should have crashed");
}

/// End-to-end GP parity over the shared-memory transport: training and
/// prediction match the in-process placement to 1e-8 at fixed seeds —
/// the same contract the TCP transport holds.
#[test]
fn shm_exact_gp_matches_inprocess_training_and_prediction() {
    let (x, y, xt) = dataset(220, 11);
    let noise = 0.05;
    let engine = || Engine::Bbmm(BbmmEngine::new(150, 8, 8, 42));
    let mut reference = ExactGp::over(
        Box::new(ShardedCovOp::new(x.clone(), Box::new(Matern32::new(0.6, 1.0)), 5)),
        y.clone(),
        noise,
        engine(),
    );
    let kernel = Matern32::new(0.6, 1.0);
    let proc = Arc::new(
        MultiProcessBackend::launch_with(
            x.clone(),
            &kernel,
            noise,
            5,
            2,
            4,
            worker_launch(),
            Transport::Shm(ShmOptions::default()),
            NumaMode::Auto,
        )
        .expect("fork shard workers over shm"),
    );
    assert!(proc.shm_active(), "{}", proc.describe());
    let routed = ShardedCovOp::new(x, Box::new(Matern32::new(0.6, 1.0)), 5)
        .with_backend(proc.clone() as Arc<dyn ShardBackend>);
    let mut distributed = ExactGp::over(Box::new(routed), y, noise, engine());

    let g_ref = reference.mll_and_grad();
    let g_dist = distributed.mll_and_grad();
    let mll_diff = (g_dist.nmll - g_ref.nmll).abs() / g_ref.nmll.abs().max(1.0);
    assert!(mll_diff < 1e-8, "nmll rel diff {mll_diff}");
    assert!(rel_diff(&g_dist.grad, &g_ref.grad) < 1e-8);
    let p_ref = reference.predict(&xt);
    let p_dist = distributed.predict(&xt);
    assert!(rel_diff(&p_dist.mean, &p_ref.mean) < 1e-8);
    assert!(rel_diff(&p_dist.var, &p_ref.var) < 1e-8);
    assert_eq!(proc.stats().bytes_tx, 0, "training leaked payload onto the socket");
}

/// SIGKILL one worker mid-solve **on the shared-memory lane**: the
/// doorbell wait must discover the death, respawn + re-attach the slot,
/// re-post the round, and finish bit-identically to a crash-free run —
/// without ever serializing payload onto the socket.
#[test]
fn shm_worker_crash_mid_solve_recovers_bit_identically() {
    let n = 160;
    let (x, _y, _xt) = dataset(n, 21);
    let mut rng = Rng::new(22);
    let b = Mat::from_fn(n, 3, |_, _| rng.normal());
    let kernel = Rbf::new(0.6, 1.0);
    let proc = Arc::new(
        MultiProcessBackend::launch_with(
            x.clone(),
            &kernel,
            0.25,
            4,
            2,
            4,
            WorkerLaunch {
                heartbeat_ms: 0, // recovery must come from the round itself
                ..worker_launch()
            },
            Transport::Shm(ShmOptions::default()),
            NumaMode::Auto,
        )
        .expect("fork shard workers over shm"),
    );
    assert!(proc.shm_active(), "{}", proc.describe());
    let routed = ShardedKernelOp::new(x, Box::new(Rbf::new(0.6, 1.0)), 0.25, 4)
        .with_backend(proc.clone() as Arc<dyn ShardBackend>);
    let opts = MbcgOptions {
        max_iters: 20,
        tol: 0.0,
        n_solve_only: usize::MAX,
    };
    let want = mbcg_op(&routed, &b, |r| r.clone(), &opts);
    let calls = Cell::new(0usize);
    let got = mbcg_op(
        &routed,
        &b,
        |r| {
            calls.set(calls.get() + 1);
            if calls.get() == 3 {
                proc.kill_worker(0);
            }
            r.clone()
        },
        &opts,
    );
    assert!(calls.get() > 3, "the kill must land mid-solve");
    assert_eq!(got.iterations, want.iterations);
    assert!(
        got.solves.max_abs_diff(&want.solves) == 0.0,
        "shm crash recovery changed the solve: diff {}",
        got.solves.max_abs_diff(&want.solves)
    );
    let stats = proc.stats();
    assert!(stats.restarts >= 1, "the killed worker was never respawned");
    assert_eq!(
        stats.bytes_tx, 0,
        "recovery must re-attach the segment, not fall back to TCP"
    );
    assert!(proc.shm_active(), "the respawned slot must rejoin the shm lane");
}

/// A requested shm transport whose segment cannot map (directory does
/// not exist) must degrade to the TCP data plane at launch — same
/// answers, the cause in `describe()`, zero `shm_rounds`, payload back
/// on the socket.
#[test]
fn shm_mapping_failure_falls_back_to_tcp_transport() {
    let n = 120;
    let (x, _y, _xt) = dataset(n, 61);
    let mut rng = Rng::new(62);
    let m = Mat::from_fn(n, 4, |_, _| rng.normal());
    let kernel = Rbf::new(0.7, 1.1);
    let inproc = ShardedCovOp::new(x.clone(), Box::new(Rbf::new(0.7, 1.1)), 4);
    let no_such_dir = std::env::temp_dir().join(format!(
        "bbmm-shm-missing-{}-{}",
        std::process::id(),
        line!()
    ));
    assert!(!no_such_dir.exists());
    let proc = Arc::new(
        MultiProcessBackend::launch_with(
            x.clone(),
            &kernel,
            0.0,
            4,
            2,
            4,
            worker_launch(),
            Transport::Shm(ShmOptions {
                dir: Some(no_such_dir),
                t_max: 0,
            }),
            NumaMode::Off,
        )
        .expect("launch must survive an unmappable segment"),
    );
    assert!(!proc.shm_active());
    assert!(
        proc.describe().contains("shm unavailable"),
        "{}",
        proc.describe()
    );
    let routed = ShardedCovOp::new(x, Box::new(Rbf::new(0.7, 1.1)), 4)
        .with_backend(proc.clone() as Arc<dyn ShardBackend>);
    let want = inproc.matmul(&m);
    let scale = want.fro_norm().max(1.0);
    let diff = routed.matmul(&m).max_abs_diff(&want) / scale;
    assert!(diff < 1e-10, "fallback value product: rel diff {diff}");
    let stats = proc.stats();
    assert_eq!(stats.shm_rounds, 0, "no segment, no shm rounds");
    assert!(stats.bytes_tx > 0 && stats.bytes_rx > 0, "payload must ride TCP");
}

/// Rounds wider than the segment's probe capacity fall back to TCP *per
/// round* while narrow rounds keep the zero-copy lane — both produce the
/// in-process answer.
#[test]
fn rounds_wider_than_the_segment_fall_back_per_round() {
    let n = 96;
    let (x, _y, _xt) = dataset(n, 71);
    let mut rng = Rng::new(72);
    let kernel = Rbf::new(0.6, 1.0);
    let inproc = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.6, 1.0)), 0.25, 4);
    let proc = MultiProcessBackend::launch_with(
        x,
        &kernel,
        0.25,
        4,
        2,
        4,
        WorkerLaunch {
            heartbeat_ms: 0,
            ..worker_launch()
        },
        Transport::Shm(ShmOptions {
            dir: None,
            t_max: 2, // narrower than the wide round below
        }),
        NumaMode::Off,
    )
    .expect("fork shard workers over shm");
    assert!(proc.shm_active(), "{}", proc.describe());

    // wide round (t = 5 > t_max = 2): per-round TCP fallback
    let wide = Mat::from_fn(n, 5, |_, _| rng.normal());
    let mut got = Mat::zeros(n, 5);
    proc.matmul_block(&ShardBlock::Value { noise: Some(0.25) }, &wide, &mut got);
    let want = inproc.matmul(&wide);
    assert!(got.max_abs_diff(&want) / want.fro_norm().max(1.0) < 1e-10);
    let after_wide = proc.stats();
    assert_eq!(after_wide.shm_rounds, 0, "a too-wide round must not claim shm");
    assert!(after_wide.bytes_tx > 0, "the wide round must ride TCP");

    // narrow round (t = 2 ≤ t_max): back on the zero-copy lane
    let narrow = Mat::from_fn(n, 2, |_, _| rng.normal());
    let mut got2 = Mat::zeros(n, 2);
    proc.matmul_block(&ShardBlock::Value { noise: Some(0.25) }, &narrow, &mut got2);
    let want2 = inproc.matmul(&narrow);
    assert!(got2.max_abs_diff(&want2) / want2.fro_norm().max(1.0) < 1e-10);
    let after_narrow = proc.stats();
    assert_eq!(after_narrow.shm_rounds, 1, "the narrow round must ride shm");
    assert_eq!(
        after_narrow.bytes_tx, after_wide.bytes_tx,
        "the narrow round must move no payload bytes"
    );
}
