//! Failure-injection tests: the stack must fail loudly and precisely on
//! bad inputs, and degrade gracefully where the paper's method does
//! (early CG termination, non-PD rescue, server protocol errors).

use bbmm_gp::data::loader::parse_csv;
use bbmm_gp::kernels::{DenseKernelOp, Rbf};
use bbmm_gp::linalg::cholesky::Cholesky;
use bbmm_gp::linalg::mbcg::{mbcg, MbcgOptions};
use bbmm_gp::linalg::op::LinearOp;
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::Rng;

#[test]
fn cholesky_reports_failing_pivot() {
    // indefinite matrix: error names the pivot where it broke
    let a = Mat::from_vec(3, 3, vec![1.0, 0.0, 0.0, 0.0, -2.0, 0.0, 0.0, 0.0, 1.0]);
    let err = match Cholesky::new(&a) {
        Err(e) => e,
        Ok(_) => panic!("indefinite matrix must not factor"),
    };
    assert_eq!(err.pivot, 1);
    assert!(err.value < 0.0);
    assert!(err.to_string().contains("pivot 1"));
}

#[test]
fn cholesky_jitter_escalation_is_bounded() {
    // a PSD-but-singular matrix gets rescued with small jitter, and the
    // jitter actually used is recorded
    let v = [1.0, 2.0, 3.0, 4.0];
    let a = Mat::from_fn(4, 4, |r, c| v[r] * v[c]);
    let ch = Cholesky::new_with_jitter(&a).unwrap();
    assert!(ch.jitter > 0.0 && ch.jitter < 1.0);
}

#[test]
fn mbcg_with_nan_rhs_does_not_hang() {
    let mut rng = Rng::new(1);
    let g = Mat::from_fn(10, 10, |_, _| rng.normal());
    let mut a = g.t_matmul(&g);
    a.add_diag(10.0);
    let mut b = Mat::zeros(10, 2);
    b.set(0, 0, f64::NAN);
    b.set(0, 1, 1.0);
    let res = mbcg(
        |m| a.matmul(m),
        &b,
        |m| m.clone(),
        &MbcgOptions {
            max_iters: 20,
            tol: 1e-10,
            n_solve_only: 0,
        },
    );
    // the NaN column freezes; the healthy column still solves
    assert!(res.iterations <= 20);
    let healthy = res.solves.col(1);
    assert!(healthy.iter().all(|v| v.is_finite()));
}

#[test]
fn mbcg_zero_iterations_budget() {
    let a = Mat::eye(5);
    let b = Mat::from_vec(5, 1, vec![1.0; 5]);
    let res = mbcg(
        |m: &Mat| a.matmul(m),
        &b,
        |m| m.clone(),
        &MbcgOptions {
            max_iters: 0,
            tol: 1e-10,
            n_solve_only: 0,
        },
    );
    assert_eq!(res.iterations, 0);
    // no progress made, solution is the zero initial guess
    assert!(res.solves.col(0).iter().all(|&v| v == 0.0));
}

#[test]
#[should_panic]
fn operator_rejects_wrong_rhs_height() {
    let mut rng = Rng::new(2);
    let x = Mat::from_fn(8, 2, |_, _| rng.uniform());
    let op = DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), 0.1);
    let bad = Mat::zeros(9, 1);
    // internal gemm catches the mismatched height
    let _ = op.matmul(&bad);
}

#[test]
#[should_panic]
fn dense_kernel_op_rejects_nonpositive_noise() {
    let x = Mat::zeros(4, 1);
    let _ = DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), 0.0);
}

#[test]
fn csv_parser_reports_line_numbers() {
    let err = parse_csv("1,2\n3,4\nbad,row\n").unwrap_err();
    assert!(err.contains("line 3"), "{err}");
    let err2 = parse_csv("1,2\n3\n").unwrap_err();
    assert!(err2.contains("line 2"), "{err2}");
}

#[test]
fn server_handles_malformed_requests_without_dying() {
    use bbmm_gp::coordinator::batcher::{BatchPolicy, DynamicBatcher, PredictFn};
    use bbmm_gp::coordinator::server::handle_line;
    use bbmm_gp::gp::predict::Prediction;
    let f: PredictFn = Box::new(|xs: &Mat| Prediction {
        mean: vec![0.0; xs.rows()],
        var: vec![0.0; xs.rows()],
    });
    let b = DynamicBatcher::new(3, BatchPolicy::default(), f);
    for bad in ["", "a,b,c", "1.0", "1,2,3,4", "NaN,1,2 extra"] {
        let resp = handle_line(bad, &b);
        assert!(resp.starts_with("ERR"), "{bad:?} -> {resp}");
    }
    // still serves good requests afterwards
    let good = handle_line("1,2,3", &b);
    assert!(!good.starts_with("ERR"), "{good}");
    let errors = b.metrics.errors.load(std::sync::atomic::Ordering::Relaxed);
    assert!(errors >= 4);
}

#[test]
fn sym_tridiag_guards_tiny_ritz_values() {
    use bbmm_gp::linalg::tridiag::SymTridiagEig;
    // a tridiagonal with a ~zero eigenvalue must not produce -inf logdet
    let eig = SymTridiagEig::new(&[1.0, 1e-320], &[0.0]);
    let q = eig.log_quadrature();
    assert!(q.is_finite());
}

#[test]
fn degenerate_dataset_single_point() {
    // 1-point GP: everything still works
    let x = Mat::from_vec(1, 1, vec![0.5]);
    let op = DenseKernelOp::new(x, Box::new(Rbf::new(1.0, 1.0)), 0.1);
    let k = op.dense();
    assert_eq!(k.shape(), (1, 1));
    let ch = Cholesky::new(&k).unwrap();
    let sol = ch.solve_vec(&[2.0]);
    assert!((sol[0] - 2.0 / 1.1).abs() < 1e-12);
    let res = mbcg(
        |m| op.matmul(m),
        &Mat::from_vec(1, 1, vec![2.0]),
        |m| m.clone(),
        &MbcgOptions::default(),
    );
    assert!((res.solves.get(0, 0) - 2.0 / 1.1).abs() < 1e-10);
}

#[test]
fn ski_clamps_out_of_grid_test_points() {
    use bbmm_gp::gp::SkiOp;
    let mut rng = Rng::new(3);
    let z: Vec<f64> = (0..50).map(|_| rng.uniform()).collect();
    let op = SkiOp::new(z, 32, Box::new(Rbf::new(0.3, 1.0)), 0.1);
    // test features far outside the training range: clamped, finite
    let cross = op.cross(&[-100.0, 0.5, 100.0]);
    assert!(cross.data().iter().all(|v| v.is_finite()));
}

#[test]
fn trainer_survives_nan_gradient_step() {
    use bbmm_gp::gp::mll::MllGrad;
    use bbmm_gp::train::{TrainConfig, Trainer};
    // an objective that emits one NaN gradient mid-run: Adam (and the
    // history) must stay finite afterwards because we keep raw params
    let mut trainer = Trainer::new(TrainConfig {
        iters: 10,
        lr: 0.1,
        ..Default::default()
    });
    let mut params = vec![0.0];
    let mut call = 0;
    trainer.run(&mut params, |p| {
        call += 1;
        let g = if call == 3 { f64::NAN } else { 2.0 * p[0] - 1.0 };
        MllGrad {
            nmll: p[0] * p[0],
            grad: vec![g],
            iterations: 1,
            logdet: 0.0,
            datafit: 0.0,
        }
    });
    assert_eq!(trainer.history.len(), 10);
    // NaN poisons Adam state; this test documents the current behaviour:
    // the parameter becomes NaN (loud, visible in history) rather than
    // silently wrong.
    assert!(params[0].is_nan() || params[0].is_finite());
}
