//! LOVE posterior integration: full-rank LOVE variances must agree with
//! the dense-Cholesky posterior across every operator family (exact,
//! SGPR, SKI), the `PosteriorCache` must invalidate when `set_params`
//! moves the operator fingerprint, correlated posterior samples must
//! reproduce the analytic posterior moments, and the `VAR`/`SAMPLE`
//! protocol verbs must round-trip through a live two-tenant TCP
//! deployment answering from cached factors.

use bbmm_gp::coordinator::{
    multi_served_predictor_love, serve_with_love, BatchPolicy, DynamicBatcher, LoveServeCtx,
    ServableModel, ServerConfig, TenantSpec,
};
use bbmm_gp::gp::predict::{predict, Prediction};
use bbmm_gp::gp::{LovePosterior, PosteriorCache, SgprOp, SkiOp};
use bbmm_gp::kernels::{DenseKernelOp, Kernel, Matern52, Rbf};
use bbmm_gp::linalg::cholesky::Cholesky;
use bbmm_gp::linalg::op::{LinearOp, SolveOptions};
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::Rng;
use std::io::{BufRead, BufReader, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn tight_opts() -> SolveOptions {
    SolveOptions {
        max_iters: 400,
        tol: 1e-12,
        precond_rank: 5,
    }
}

/// Dense-Cholesky posterior for any operator, using the *same* cross
/// block and prior diagonal as the LOVE path — the ground truth LOVE
/// must reproduce at full rank.
fn dense_posterior(op: &dyn LinearOp, y: &[f64], k_star: &Mat, diag: &[f64]) -> Prediction {
    let ch = Cholesky::new_with_jitter(&op.dense()).unwrap();
    predict(k_star, diag, |m| ch.solve_mat(m), y)
}

fn assert_close(got: &Prediction, want: &Prediction, tag: &str) {
    for j in 0..want.mean.len() {
        assert!(
            (got.mean[j] - want.mean[j]).abs() <= 1e-6 * want.mean[j].abs().max(1.0),
            "{tag} mean {j}: {} vs {}",
            got.mean[j],
            want.mean[j]
        );
        assert!(
            (got.var[j] - want.var[j]).abs() <= 1e-6 * want.var[j].abs().max(1e-9),
            "{tag} var {j}: {} vs {}",
            got.var[j],
            want.var[j]
        );
    }
}

#[test]
fn love_matches_dense_posterior_for_exact_operator() {
    let n = 70;
    let mut rng = Rng::new(11);
    let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let y: Vec<f64> = (0..n)
        .map(|i| (2.5 * x.get(i, 0)).sin() + 0.4 * x.get(i, 1) + 0.02 * rng.normal())
        .collect();
    let op = DenseKernelOp::new(x, Box::new(Rbf::new(0.6, 1.2)), 0.05);
    let xs = Mat::from_fn(8, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let k_star = op.cross(&xs, op.x());
    let diag: Vec<f64> = (0..8).map(|i| op.kernel().eval(xs.row(i), xs.row(i))).collect();

    let post = LovePosterior::build(&op, &y, n, &tight_opts());
    assert_close(&post.predict(&k_star, &diag), &dense_posterior(&op, &y, &k_star, &diag), "exact");
}

#[test]
fn love_matches_dense_posterior_for_sgpr_operator() {
    let n = 90;
    let m = 20;
    let mut rng = Rng::new(12);
    let x = Mat::from_fn(n, 1, |_, _| rng.uniform_in(-2.0, 2.0));
    let u = Mat::from_fn(m, 1, |i, _| -2.0 + 4.0 * (i as f64 + 0.5) / m as f64);
    let y: Vec<f64> = (0..n).map(|i| x.get(i, 0).sin() + 0.05 * rng.normal()).collect();
    let op = SgprOp::new(x, u, Box::new(Rbf::new(0.7, 1.0)), 0.1);
    let xs = Mat::from_fn(6, 1, |_, _| rng.uniform_in(-2.0, 2.0));
    // SoR-consistent cross block: the same K(X*,U)K_UU⁻¹K(U,X) the
    // operator itself represents, so the dense reference and LOVE see
    // identical posterior algebra
    let k_star = op.cross_sor(&xs);
    let diag: Vec<f64> = (0..6).map(|i| op.kernel().eval(xs.row(i), xs.row(i))).collect();

    // full-rank request; Lanczos truncates on the rank-(m+1)-ish
    // invariant subspace of the SoR operator and stays exact
    let post = LovePosterior::build(&op, &y, n, &tight_opts());
    assert!(post.rank() <= m + 2, "SoR Lanczos should truncate: rank={}", post.rank());
    assert_close(&post.predict(&k_star, &diag), &dense_posterior(&op, &y, &k_star, &diag), "sgpr");
}

#[test]
fn love_matches_dense_posterior_for_ski_operator() {
    let n = 80;
    let mut rng = Rng::new(13);
    let z: Vec<f64> = (0..n).map(|_| rng.uniform_in(-3.0, 3.0)).collect();
    let y: Vec<f64> = z.iter().map(|&zi| (1.3 * zi).cos() + 0.05 * rng.normal()).collect();
    let op = SkiOp::new(z, 64, Box::new(Matern52::new(0.8, 1.0)), 0.08);
    let z_test: Vec<f64> = (0..5).map(|_| rng.uniform_in(-2.5, 2.5)).collect();
    // SKI-consistent cross block W* K_UU Wᵀ — matches the served path
    let k_star = op.cross(&z_test);
    let diag: Vec<f64> = z_test.iter().map(|&zt| op.kernel().eval(&[zt], &[zt])).collect();

    let post = LovePosterior::build(&op, &y, n, &tight_opts());
    assert_close(&post.predict(&k_star, &diag), &dense_posterior(&op, &y, &k_star, &diag), "ski");
}

#[test]
fn posterior_cache_invalidates_when_set_params_moves_the_fingerprint() {
    let n = 50;
    let mut rng = Rng::new(14);
    let z: Vec<f64> = (0..n).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
    let y: Vec<f64> = z.iter().map(|&zi| zi.sin()).collect();
    let mut op = SkiOp::new(z, 40, Box::new(Rbf::new(0.5, 1.0)), 0.1);
    let cache = PosteriorCache::new();
    let opts = tight_opts();

    let p1 = cache.get_or_build("ski", &op, &y, 24, &opts);
    let p2 = cache.get_or_build("ski", &op, &y, 24, &opts);
    assert!(Arc::ptr_eq(&p1, &p2), "unchanged operator must hit the cache");
    assert_eq!((cache.misses(), cache.hits(), cache.invalidations()), (1, 1, 0));

    // a sweep/training step rewrites the kernel hyperparameters: the
    // operator content fingerprint moves and the stale posterior must go
    let mut raw = op.params();
    raw[0] += 0.4;
    op.set_params(&raw);
    let p3 = cache.get_or_build("ski", &op, &y, 24, &opts);
    assert!(!Arc::ptr_eq(&p2, &p3), "stale posterior served after set_params");
    assert_eq!(p3.fingerprint(), op.fingerprint());
    assert_eq!((cache.misses(), cache.hits(), cache.invalidations()), (1, 1, 1));
    assert_eq!(cache.len(), 1);
}

#[test]
fn sample_covariance_of_many_draws_matches_the_analytic_posterior() {
    let n = 45;
    let mut rng = Rng::new(15);
    let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let y: Vec<f64> = (0..n)
        .map(|i| (3.0 * x.get(i, 0)).sin() - 0.5 * x.get(i, 1) + 0.02 * rng.normal())
        .collect();
    let op = DenseKernelOp::new(x, Box::new(Rbf::new(0.5, 1.0)), 0.1);
    let xs = Mat::from_vec(3, 2, vec![-0.4, 0.1, 0.0, 0.3, 0.5, -0.2]);
    let k_star = op.cross(&xs, op.x());
    let prior = op.cross(&xs, &xs);

    let post = LovePosterior::build(&op, &y, n, &tight_opts());
    let want_mean = post.predict_mean(&k_star);
    let want_cov = post.posterior_cov(&k_star, &prior);

    let m = 1500;
    let mut srng = Rng::new(16);
    let draws = post.sample(&k_star, &prior, m, &mut srng);
    let emp_mean: Vec<f64> =
        (0..3).map(|i| draws.row(i).iter().sum::<f64>() / m as f64).collect();
    for i in 0..3 {
        assert!(
            (emp_mean[i] - want_mean[i]).abs() < 0.06,
            "mean {i}: {} vs {}",
            emp_mean[i],
            want_mean[i]
        );
        // full covariance including cross terms: draws must be
        // *correlated* across test points, not independent marginals
        for j in 0..3 {
            let emp_cov = draws
                .row(i)
                .iter()
                .zip(draws.row(j).iter())
                .map(|(a, b)| (a - emp_mean[i]) * (b - emp_mean[j]))
                .sum::<f64>()
                / m as f64;
            assert!(
                (emp_cov - want_cov.get(i, j)).abs() < 0.06,
                "cov ({i},{j}): {emp_cov} vs {}",
                want_cov.get(i, j)
            );
        }
    }
}

/// An exact-GP tenant behind the serving seam (mirrors what `bbmm serve`
/// builds per tenant).
struct ExactTenant {
    op: DenseKernelOp,
    y: Vec<f64>,
}

impl ServableModel for ExactTenant {
    fn op(&self) -> &dyn LinearOp {
        &self.op
    }
    fn cross(&self, xs: &Mat) -> Mat {
        self.op.cross(xs, self.op.x())
    }
    fn prior_diag(&self, xs: &Mat) -> Vec<f64> {
        (0..xs.rows())
            .map(|i| self.op.kernel().eval(xs.row(i), xs.row(i)))
            .collect()
    }
    fn y(&self) -> &[f64] {
        &self.y
    }
}

fn tenant(n: usize, seed: u64, matern: bool, noise: f64) -> ExactTenant {
    let mut rng = Rng::new(seed);
    let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let y: Vec<f64> = (0..n)
        .map(|i| (3.0 * x.get(i, 0)).sin() - 0.5 * x.get(i, 1) + 0.02 * rng.normal())
        .collect();
    let kernel: Box<dyn Kernel> = if matern {
        Box::new(Matern52::new(0.6, 0.9))
    } else {
        Box::new(Rbf::new(0.5, 1.0))
    };
    ExactTenant {
        op: DenseKernelOp::new(x, kernel, noise),
        y,
    }
}

/// Dense-Cholesky reference posterior (mean, variance) at one point.
fn reference(t: &ExactTenant, x: &[f64]) -> (f64, f64) {
    let xs = Mat::from_vec(1, 2, x.to_vec());
    let k_star = t.op.cross(&xs, t.op.x());
    let kss = t.op.kernel().eval(xs.row(0), xs.row(0));
    let p = dense_posterior(&t.op, &t.y, &k_star, &[kss]);
    (p.mean[0], p.var[0])
}

#[test]
fn var_and_sample_verbs_roundtrip_through_a_two_tenant_deployment() {
    let n = 60;
    let ta = tenant(n, 21, false, 0.05);
    let tb = tenant(n, 22, true, 0.2);
    let probe_a = [0.25, -0.5];
    let probe_b = [-0.75, 0.1];
    let (mean_a, var_a) = reference(&ta, &probe_a);
    let (_, var_b) = reference(&tb, &probe_b);

    let posteriors = Arc::new(PosteriorCache::new());
    let models: Vec<(String, Arc<dyn ServableModel>)> = vec![
        ("alpha".to_string(), Arc::new(ta)),
        ("beta".to_string(), Arc::new(tb)),
    ];
    // full rank → LOVE variances are exact, so the wire values must
    // match the dense reference to formatting precision
    let ctx = Arc::new(LoveServeCtx::new(models, n, tight_opts(), Arc::clone(&posteriors), 7));
    let batcher = Arc::new(DynamicBatcher::new_multi(
        vec![TenantSpec::new("alpha", 2), TenantSpec::new("beta", 2)],
        BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_millis(25),
            ..BatchPolicy::default()
        },
        multi_served_predictor_love(Arc::clone(&ctx)),
    ));

    let stop = Arc::new(AtomicBool::new(false));
    let config = ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        operator: "alpha=exact(rbf) | beta=exact(matern52)".to_string(),
        shard_count: 1,
        stop: Arc::clone(&stop),
    };
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let srv = {
        let b = Arc::clone(&batcher);
        let love = Some(Arc::clone(&ctx));
        std::thread::spawn(move || {
            serve_with_love(config, b, love, move |addr| {
                addr_tx.send(addr).unwrap();
            })
            .unwrap();
        })
    };
    let addr = addr_rx.recv().unwrap();

    let mut conn = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut ask = |line: &str| -> String {
        conn.write_all(line.as_bytes()).unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp.trim().to_string()
    };

    // VAR answers per tenant from the cached root, matching the dense
    // posterior at full rank
    let got_var_a: f64 = ask(&format!("VAR alpha:{},{}\n", probe_a[0], probe_a[1]))
        .parse()
        .unwrap();
    assert!((got_var_a - var_a).abs() < 1e-6, "alpha VAR {got_var_a} vs {var_a}");
    let got_var_b: f64 = ask(&format!("VAR beta:{},{}\n", probe_b[0], probe_b[1]))
        .parse()
        .unwrap();
    assert!((got_var_b - var_b).abs() < 1e-6, "beta VAR {got_var_b} vs {var_b}");

    // ordinary mean,var lines go through the batcher but answer from the
    // SAME cached posteriors — the two paths must agree on the wire
    let line = ask(&format!("alpha:{},{}\n", probe_a[0], probe_a[1]));
    let mut fields = line.split(',');
    let line_mean: f64 = fields.next().unwrap().parse().unwrap();
    let line_var: f64 = fields.next().unwrap().parse().unwrap();
    assert!((line_mean - mean_a).abs() < 1e-5, "mean {line_mean} vs {mean_a}");
    assert!((line_var - got_var_a).abs() < 1e-8, "tick var {line_var} vs VAR {got_var_a}");

    // SAMPLE returns k finite correlated draws from the cached root
    let draws: Vec<f64> = ask(&format!("SAMPLE 8 beta:{},{}\n", probe_b[0], probe_b[1]))
        .split(',')
        .map(|v| v.parse().unwrap())
        .collect();
    assert_eq!(draws.len(), 8);
    assert!(draws.iter().all(|v| v.is_finite()));

    // protocol errors
    assert!(ask("VAR ghost:1.0,2.0\n").starts_with("ERR unknown tenant"));
    assert!(ask("VAR alpha:1.0\n").starts_with("ERR dim"));
    assert!(ask("SAMPLE 0 alpha:1.0,2.0\n").starts_with("ERR"));
    assert!(ask("SAMPLE x alpha:1.0,2.0\n").starts_with("ERR"));

    // STATS reports the posterior cache alongside the request metrics
    let stats = ask("STATS\n");
    assert!(stats.contains("posteriors=2"), "{stats}");

    stop.store(true, Ordering::Relaxed);
    srv.join().unwrap();

    // each tenant's posterior was frozen exactly once, then every verb
    // (VAR, SAMPLE, and the batched mean path) reused it
    assert_eq!(posteriors.misses(), 2, "{}", posteriors.stats());
    assert_eq!(posteriors.invalidations(), 0);
    assert!(posteriors.hits() >= 2, "{}", posteriors.stats());
}
