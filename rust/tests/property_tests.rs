//! Property-based tests (hand-rolled generator sweep; proptest is not
//! resolvable offline). Each property runs across many random shapes/seeds
//! and asserts an exact mathematical invariant — these are the Rust twins
//! of the hypothesis sweeps in python/tests/.

use bbmm_gp::kernels::{
    DenseKernelOp, Kernel, Matern32, Matern52, Rbf, ShardedKernelOp, SumKernel,
};
use bbmm_gp::linalg::cholesky::Cholesky;
use bbmm_gp::linalg::fft::{fft_inplace, Cplx};
use bbmm_gp::linalg::mbcg::{mbcg, mbcg_sharded, MbcgOptions};
use bbmm_gp::linalg::op::LinearOp;
use bbmm_gp::linalg::pivoted_cholesky::pivoted_cholesky_dense;
use bbmm_gp::linalg::toeplitz::ToeplitzOp;
use bbmm_gp::linalg::tridiag::SymTridiagEig;
use bbmm_gp::tensor::Mat;
use bbmm_gp::util::Rng;

/// random SPD matrix with controlled conditioning
fn spd(n: usize, rng: &mut Rng) -> Mat {
    let g = Mat::from_fn(n, n, |_, _| rng.normal());
    let mut a = g.t_matmul(&g);
    a.add_diag(0.5 * n as f64 * (0.2 + rng.uniform()));
    a.symmetrize();
    a
}

#[test]
fn prop_mbcg_solves_match_cholesky_across_shapes() {
    let mut rng = Rng::new(1);
    for trial in 0..30 {
        let n = 2 + rng.below(60);
        let s = 1 + rng.below(6);
        let a = spd(n, &mut rng);
        let b = Mat::from_fn(n, s, |_, _| rng.normal());
        let res = mbcg(
            |m| a.matmul(m),
            &b,
            |m| m.clone(),
            &MbcgOptions {
                max_iters: 2 * n,
                tol: 1e-12,
                n_solve_only: 0,
            },
        );
        let want = Cholesky::new(&a).unwrap().solve_mat(&b);
        assert!(
            res.solves.max_abs_diff(&want) < 1e-6,
            "trial {trial}: n={n} s={s} diff={}",
            res.solves.max_abs_diff(&want)
        );
    }
}

#[test]
fn prop_tridiag_ritz_values_inside_spectrum() {
    let mut rng = Rng::new(2);
    for _trial in 0..25 {
        let n = 5 + rng.below(40);
        let a = spd(n, &mut rng);
        let b = Mat::from_fn(n, 2, |_, _| rng.rademacher());
        let p = 2 + rng.below(n.min(15));
        let res = mbcg(
            |m| a.matmul(m),
            &b,
            |m| m.clone(),
            &MbcgOptions {
                max_iters: p,
                tol: 0.0,
                n_solve_only: 0,
            },
        );
        // Gershgorin upper bound; SPD lower bound 0
        let mut lmax = 0.0f64;
        for i in 0..n {
            lmax = lmax.max((0..n).map(|j| a.get(i, j).abs()).sum());
        }
        for t in &res.tridiags {
            if t.n() == 0 {
                continue;
            }
            let eig = SymTridiagEig::new(&t.diag, &t.offdiag);
            for &l in &eig.eigenvalues {
                assert!(l > 0.0 && l <= lmax * (1.0 + 1e-8));
            }
            // quadrature weights are a probability vector
            let wsum: f64 = eig.first_components.iter().map(|w| w * w).sum();
            assert!((wsum - 1.0).abs() < 1e-8);
        }
    }
}

#[test]
fn prop_pivoted_cholesky_error_is_psd_and_monotone() {
    let mut rng = Rng::new(3);
    for _trial in 0..20 {
        let n = 10 + rng.below(50);
        let ls = 0.1 + 0.4 * rng.uniform();
        let xs: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let k = Mat::from_fn(n, n, |i, j| {
            let d = xs[i] - xs[j];
            (-d * d / (2.0 * ls * ls)).exp()
        });
        let mut prev = f64::INFINITY;
        for rank in [1usize, 3, 6, 10] {
            let pc = pivoted_cholesky_dense(&k, rank.min(n), 0.0);
            // monotone error decay
            assert!(pc.error_trace <= prev + 1e-9);
            prev = pc.error_trace;
            // E = K − LLᵀ is PSD ⇒ jittered Cholesky succeeds
            let mut e = k.sub(&pc.l.matmul_t(&pc.l));
            e.add_diag(1e-9 * n as f64);
            assert!(
                Cholesky::new(&e).is_ok(),
                "error matrix not PSD at rank {rank}"
            );
        }
    }
}

#[test]
fn prop_kernel_operators_are_symmetric_and_psd() {
    // vᵀK̂w == wᵀK̂v and vᵀK̂v > 0 across kernel families and dims
    let mut rng = Rng::new(4);
    for trial in 0..20 {
        let n = 5 + rng.below(40);
        let d = 1 + rng.below(5);
        let x = Mat::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
        let kernel: Box<dyn bbmm_gp::kernels::Kernel> = match trial % 4 {
            0 => Box::new(Rbf::new(0.3 + rng.uniform(), 0.5 + rng.uniform())),
            1 => Box::new(Matern32::new(0.3 + rng.uniform(), 0.5 + rng.uniform())),
            2 => Box::new(Matern52::new(0.3 + rng.uniform(), 0.5 + rng.uniform())),
            _ => Box::new(SumKernel::new(
                Box::new(Rbf::new(0.5, 1.0)),
                Box::new(Matern32::new(0.7, 0.5)),
            )),
        };
        let op = DenseKernelOp::new(x, kernel, 0.01 + rng.uniform() * 0.2);
        let v = Mat::from_fn(n, 1, |_, _| rng.normal());
        let w = Mat::from_fn(n, 1, |_, _| rng.normal());
        let kv = op.matmul(&v);
        let kw = op.matmul(&w);
        let vkw: f64 = (0..n).map(|i| v.get(i, 0) * kw.get(i, 0)).sum();
        let wkv: f64 = (0..n).map(|i| w.get(i, 0) * kv.get(i, 0)).sum();
        assert!(
            (vkw - wkv).abs() < 1e-8 * (1.0 + vkw.abs()),
            "symmetry violated: {vkw} vs {wkv}"
        );
        let vkv: f64 = (0..n).map(|i| v.get(i, 0) * kv.get(i, 0)).sum();
        assert!(vkv > 0.0, "not PD: vᵀK̂v = {vkv}");
    }
}

#[test]
fn prop_fft_roundtrip_and_linearity() {
    let mut rng = Rng::new(5);
    for _ in 0..20 {
        let log_n = 1 + rng.below(9);
        let n = 1usize << log_n;
        let x: Vec<Cplx> = (0..n).map(|_| Cplx::new(rng.normal(), rng.normal())).collect();
        let y: Vec<Cplx> = (0..n).map(|_| Cplx::new(rng.normal(), rng.normal())).collect();
        // roundtrip
        let mut buf = x.clone();
        fft_inplace(&mut buf, false);
        fft_inplace(&mut buf, true);
        for i in 0..n {
            assert!((buf[i].re - x[i].re).abs() < 1e-9);
            assert!((buf[i].im - x[i].im).abs() < 1e-9);
        }
        // linearity: F(x+y) == F(x)+F(y)
        let mut fx = x.clone();
        fft_inplace(&mut fx, false);
        let mut fy = y.clone();
        fft_inplace(&mut fy, false);
        let mut fxy: Vec<Cplx> = (0..n).map(|i| x[i].add(y[i])).collect();
        fft_inplace(&mut fxy, false);
        for i in 0..n {
            let s = fx[i].add(fy[i]);
            assert!((fxy[i].re - s.re).abs() < 1e-8);
            assert!((fxy[i].im - s.im).abs() < 1e-8);
        }
    }
}

#[test]
fn prop_toeplitz_matches_dense_across_sizes() {
    let mut rng = Rng::new(6);
    for _ in 0..20 {
        let m = 1 + rng.below(120);
        let col: Vec<f64> = (0..m).map(|i| rng.normal() / (1.0 + i as f64)).collect();
        let op = ToeplitzOp::new(col);
        let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        let got = op.matvec(&v);
        let want = op.to_dense().matvec(&v);
        for i in 0..m {
            assert!((got[i] - want[i]).abs() < 1e-8, "m={m} i={i}");
        }
    }
}

#[test]
fn prop_cholesky_logdet_consistent_with_eigen_sum() {
    // logdet(A) computed from Cholesky must equal SLQ over a full Lanczos
    let mut rng = Rng::new(7);
    for _ in 0..10 {
        let n = 4 + rng.below(16);
        let a = spd(n, &mut rng);
        let ld = Cholesky::new(&a).unwrap().logdet();
        let z = rng.normal_vec(n);
        let (t, _q) = bbmm_gp::linalg::lanczos::lanczos_tridiag(|v| a.matvec(v), &z, n);
        let eig = SymTridiagEig::new(&t.diag, &t.offdiag);
        let ld_l: f64 = eig.eigenvalues.iter().map(|l| l.ln()).sum();
        assert!((ld - ld_l).abs() < 1e-6 * ld.abs().max(1.0));
    }
}

#[test]
fn prop_preconditioned_mbcg_same_solution_as_plain() {
    // preconditioning changes the path, never the answer
    let mut rng = Rng::new(8);
    for _ in 0..10 {
        let n = 20 + rng.below(60);
        let xs: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        let mut k = Mat::from_fn(n, n, |i, j| {
            let d = xs[i] - xs[j];
            (-d * d / 0.02).exp()
        });
        let noise = 1e-2;
        k.add_diag(noise);
        let b = Mat::col_from_slice(&rng.normal_vec(n));
        let mut k_nl = k.clone();
        k_nl.add_diag(-noise);
        let pc = pivoted_cholesky_dense(&k_nl, 6, 0.0);
        let pre = bbmm_gp::linalg::preconditioner::PartialCholPrecond::new(pc.l, noise);
        use bbmm_gp::linalg::preconditioner::Preconditioner;
        let plain = mbcg(
            |m| k.matmul(m),
            &b,
            |m| m.clone(),
            &MbcgOptions {
                max_iters: 4 * n,
                tol: 1e-11,
                n_solve_only: 1,
            },
        );
        let precond = mbcg(
            |m| k.matmul(m),
            &b,
            |m| pre.solve_mat(m),
            &MbcgOptions {
                max_iters: 4 * n,
                tol: 1e-11,
                n_solve_only: 1,
            },
        );
        assert!(
            plain.solves.max_abs_diff(&precond.solves) < 1e-5,
            "solutions diverge: {}",
            plain.solves.max_abs_diff(&precond.solves)
        );
        assert!(precond.iterations <= plain.iterations);
    }
}

#[test]
fn prop_sharded_matmul_matches_dense_across_shard_counts_and_scalars() {
    // ShardedKernelOp must reproduce DenseKernelOp to 1e-10 for every shard
    // count (1, 3, 7, n) and every kernel family (incl. the non-stationary
    // composite path), and its f32 accumulation must track f64 to f32
    // accuracy.
    let mut rng = Rng::new(11);
    for trial in 0..12 {
        let n = 10 + rng.below(60);
        let d = 1 + rng.below(4);
        let x = Mat::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
        let noise = 0.05 + 0.2 * rng.uniform();
        let kernel: Box<dyn Kernel> = match trial % 4 {
            0 => Box::new(Rbf::new(0.3 + rng.uniform(), 0.5 + rng.uniform())),
            1 => Box::new(Matern32::new(0.3 + rng.uniform(), 0.5 + rng.uniform())),
            2 => Box::new(Matern52::new(0.3 + rng.uniform(), 0.5 + rng.uniform())),
            _ => Box::new(SumKernel::new(
                Box::new(Rbf::new(0.5, 1.0)),
                Box::new(Matern32::new(0.7, 0.5)),
            )),
        };
        let dense = DenseKernelOp::new(x.clone(), kernel.boxed_clone(), noise);
        let t = 1 + rng.below(4);
        let m = Mat::from_fn(n, t, |_, _| rng.normal());
        let want = dense.matmul(&m);
        for &s in &[1usize, 3, 7, n] {
            let tile = 1 + rng.below(16);
            let op = ShardedKernelOp::new(x.clone(), kernel.boxed_clone(), noise, s)
                .with_tile(tile);
            let got = op.matmul(&m);
            assert!(
                got.max_abs_diff(&want) < 1e-10,
                "trial {trial} shards {s} tile {tile}: {}",
                got.max_abs_diff(&want)
            );
            // derivative operators must shard identically
            let p = rng.below(dense.n_params());
            let dgot = op.dmatmul(p, &m);
            let dwant = dense.dmatmul(p, &m);
            assert!(
                dgot.max_abs_diff(&dwant) < 1e-10,
                "trial {trial} shards {s} dparam {p}"
            );
            // f32 accumulation stays within f32 round-off of the f64 result
            let got32 = op.matmul_scalar::<f32>(&m.cast());
            let diff32 = got32.cast::<f64>().max_abs_diff(&want);
            assert!(
                diff32 < 1e-3 * (1.0 + want.fro_norm()),
                "trial {trial} shards {s} f32 diff {diff32}"
            );
        }
    }
}

#[test]
fn prop_mbcg_sharded_solves_match_monolithic_and_cholesky() {
    // the shard-assembled mmm_A path changes the schedule, never the answer
    let mut rng = Rng::new(12);
    for trial in 0..10 {
        let n = 15 + rng.below(50);
        let x = Mat::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
        let noise = 0.1 + 0.2 * rng.uniform();
        let shards = 1 + rng.below(6);
        let op = ShardedKernelOp::new(x.clone(), Box::new(Rbf::new(0.6, 1.0)), noise, shards);
        let dense = DenseKernelOp::new(x, Box::new(Rbf::new(0.6, 1.0)), noise);
        let s = 1 + rng.below(4);
        let b = Mat::from_fn(n, s, |_, _| rng.normal());
        let opts = MbcgOptions {
            max_iters: 2 * n,
            tol: 1e-12,
            n_solve_only: 0,
        };
        let shrd = mbcg_sharded(&op, &b, |m| m.clone(), &opts);
        let mono = mbcg(|m| dense.matmul(m), &b, |m| m.clone(), &opts);
        assert!(
            shrd.solves.max_abs_diff(&mono.solves) < 1e-8,
            "trial {trial}: {}",
            shrd.solves.max_abs_diff(&mono.solves)
        );
        let want = Cholesky::new(&dense.dense()).unwrap().solve_mat(&b);
        assert!(shrd.solves.max_abs_diff(&want) < 1e-6, "trial {trial}");
    }
}

#[test]
fn prop_batcher_preserves_request_response_pairing() {
    // random concurrent load: every response must match its request
    use bbmm_gp::coordinator::batcher::{BatchPolicy, DynamicBatcher, PredictFn};
    use bbmm_gp::gp::predict::Prediction;
    use std::sync::Arc;
    let f: PredictFn = Box::new(|xs: &Mat| Prediction {
        mean: (0..xs.rows()).map(|i| 10.0 * xs.get(i, 0) + xs.get(i, 1)).collect(),
        var: (0..xs.rows()).map(|i| xs.get(i, 0)).collect(),
    });
    let b = Arc::new(DynamicBatcher::new(
        2,
        BatchPolicy {
            max_batch: 7,
            max_wait: std::time::Duration::from_millis(1),
            ..BatchPolicy::default()
        },
        f,
    ));
    let mut handles = Vec::new();
    for t in 0..8 {
        let b = Arc::clone(&b);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t);
            for _ in 0..25 {
                let a = rng.uniform();
                let c = rng.uniform();
                let (mean, var) = b.predict_one(vec![a, c]).unwrap();
                assert!((mean - (10.0 * a + c)).abs() < 1e-12);
                assert!((var - a).abs() < 1e-12);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}
